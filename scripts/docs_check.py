#!/usr/bin/env python
"""Docs coverage / link check (``make docs-check``).

Verifies that the documentation keeps up with the code:

  1. every package directory under ``src/repro/`` is mentioned by name
     somewhere in README.md or docs/*.md;
  2. every relative link and bare file reference in README.md and
     docs/*.md resolves to a real file in the repo;
  3. every ``benchmarks/bench_*.py`` entry point is documented in
     docs/benchmarks.md.

Exits non-zero with a report on failure. Wired into scripts/tier1.sh as
a non-fatal step (docs drift should nag, not block the test gate).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def doc_files():
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def main() -> int:
    problems = []
    docs = doc_files()
    if not (ROOT / "README.md").exists():
        problems.append("README.md is missing")
    if not (ROOT / "docs").is_dir():
        problems.append("docs/ directory is missing")
    corpus = "\n".join(f.read_text() for f in docs)

    # 1) every src/repro/* package is mentioned somewhere in the docs
    for pkg in sorted((ROOT / "src" / "repro").iterdir()):
        if not pkg.is_dir() or pkg.name.startswith("__"):
            continue
        if pkg.name not in corpus:
            problems.append(
                f"package src/repro/{pkg.name}/ is not mentioned in "
                f"README.md or docs/")

    # 2) markdown links + bare path references resolve
    path_re = re.compile(
        r"\]\(([^)]+?)\)"                     # [text](target[#anchor])
        r"|`((?:src|docs|benchmarks|scripts|tests|examples)"
        r"/[\w./-]+?)(?:::[\w.]+)?`")         # `path/to/file.py::anchor`
    for f in docs:
        for m in path_re.finditer(f.read_text()):
            target = (m.group(1) or m.group(2)).split("#", 1)[0]
            if not target or target.startswith(
                    ("http://", "https://", "mailto:")):
                continue
            resolved = (f.parent / target).resolve()
            alt = (ROOT / target).resolve()
            if not resolved.exists() and not alt.exists():
                problems.append(f"{f.relative_to(ROOT)}: broken link "
                                f"-> {target}")

    # 3) every benchmark entry point is documented
    bench_doc = ROOT / "docs" / "benchmarks.md"
    bench_text = bench_doc.read_text() if bench_doc.exists() else ""
    for bench in sorted((ROOT / "benchmarks").glob("bench_*.py")):
        if bench.name not in bench_text:
            problems.append(
                f"benchmarks/{bench.name} is not documented in "
                f"docs/benchmarks.md")

    if problems:
        print("docs-check FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"docs-check OK: {len(docs)} docs, all packages mentioned, "
          f"all links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
