#!/usr/bin/env python
"""Docs coverage / link check (``make docs-check``).

Verifies that the documentation keeps up with the code:

  1. every package directory under ``src/repro/`` is mentioned by name
     somewhere in README.md or docs/*.md;
  2. every relative link and bare file reference in README.md and
     docs/*.md resolves to a real file in the repo;
  3. every ``benchmarks/bench_*.py`` entry point is documented in
     docs/benchmarks.md;
  4. every backticked dotted module reference (``repro.fleet.perf``,
     optionally with a trailing attribute or ``::anchor``) resolves to a
     module under ``src/``;
  5. every ``--flag`` on a ``python ...`` command line inside a fenced
     code block appears verbatim in the source of the script/module the
     command invokes (so documented CLI surfaces can't drift);
  6. every backticked ``serve_*`` / ``train_*`` metric name in
     docs/observability.md exists in ``src/repro/obs/`` (the catalog
     table can't drift from the pinned metric vocabulary);
  7. every ``benchmarks/scenarios/*.json`` validates against the
     scenario schema (``repro.fleet.scenarios::validate_scenario`` —
     unknown keys and non-reproducible seeds are rejected) and its
     ``name`` is documented somewhere in the docs corpus.

Exits non-zero with a report on failure. Wired into scripts/tier1.sh as
a *fatal* gate: docs drift blocks the tier-1 verify.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def doc_files():
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def module_ref_resolves(ref: str) -> bool:
    """``repro.a.b`` -> src/repro/a/b.py or the package src/repro/a/b/.

    A trailing component may be a function/class attribute — but only of
    a *module* (``repro.core.goodput.modeled_goodput`` is fine because
    goodput.py exists); a dangling name under a package directory
    (``repro.fleet.nonexistent``) does not resolve."""
    parts = ref.split("::", 1)[0].split(".")
    base = ROOT / "src" / Path(*parts)
    if base.with_suffix(".py").exists() or base.is_dir():
        return True
    prefix = ROOT / "src" / Path(*parts[:-1]) if len(parts) > 1 else None
    return prefix is not None and prefix.with_suffix(".py").exists()


def fenced_blocks(text: str):
    """Yield the contents of ``` fenced code blocks."""
    chunks = text.split("```")
    for i in range(1, len(chunks), 2):
        body = chunks[i]
        # drop the info string (first line, e.g. "sh" or "python")
        yield body.split("\n", 1)[1] if "\n" in body else ""


def command_target(tokens) -> Path | None:
    """The repo file a ``python ...`` command line invokes, if any."""
    for j, tok in enumerate(tokens):
        if tok == "-m" and j + 1 < len(tokens):
            mod = tokens[j + 1]
            for base in (ROOT, ROOT / "src"):
                p = base / (mod.replace(".", "/") + ".py")
                if p.exists():
                    return p
            return None
        if tok.endswith(".py"):
            p = ROOT / tok
            return p if p.exists() else None
    return None


def check_cli_flags(doc: Path, problems) -> None:
    for block in fenced_blocks(doc.read_text()):
        # join continuation lines so flags after a trailing \ attach to
        # their command
        joined = re.sub(r"\\\n\s*", " ", block)
        for line in joined.splitlines():
            if "python" not in line:
                continue
            tokens = [t.strip("[]()") for t in line.split()]
            target = command_target(tokens)
            if target is None:
                continue
            src = target.read_text()
            for tok in tokens:
                m = re.match(r"(--[A-Za-z][\w-]*)", tok)
                if m and m.group(1) not in src:
                    problems.append(
                        f"{doc.relative_to(ROOT)}: flag {m.group(1)} not "
                        f"found in {target.relative_to(ROOT)}")


def main() -> int:
    problems = []
    docs = doc_files()
    if not (ROOT / "README.md").exists():
        problems.append("README.md is missing")
    if not (ROOT / "docs").is_dir():
        problems.append("docs/ directory is missing")
    corpus = "\n".join(f.read_text() for f in docs)

    # 1) every src/repro/* package is mentioned somewhere in the docs
    for pkg in sorted((ROOT / "src" / "repro").iterdir()):
        if not pkg.is_dir() or pkg.name.startswith("__"):
            continue
        if pkg.name not in corpus:
            problems.append(
                f"package src/repro/{pkg.name}/ is not mentioned in "
                f"README.md or docs/")

    # 2) markdown links + bare path references resolve
    path_re = re.compile(
        r"\]\(([^)]+?)\)"                     # [text](target[#anchor])
        r"|`((?:src|docs|benchmarks|scripts|tests|examples)"
        r"/[\w./-]+?)(?:::[\w.]+)?`")         # `path/to/file.py::anchor`
    for f in docs:
        for m in path_re.finditer(f.read_text()):
            target = (m.group(1) or m.group(2)).split("#", 1)[0]
            if not target or target.startswith(
                    ("http://", "https://", "mailto:")):
                continue
            resolved = (f.parent / target).resolve()
            alt = (ROOT / target).resolve()
            if not resolved.exists() and not alt.exists():
                problems.append(f"{f.relative_to(ROOT)}: broken link "
                                f"-> {target}")

    # 3) every benchmark entry point is documented
    bench_doc = ROOT / "docs" / "benchmarks.md"
    bench_text = bench_doc.read_text() if bench_doc.exists() else ""
    for bench in sorted((ROOT / "benchmarks").glob("bench_*.py")):
        if bench.name not in bench_text:
            problems.append(
                f"benchmarks/{bench.name} is not documented in "
                f"docs/benchmarks.md")

    # 4) backticked dotted module references resolve under src/
    mod_re = re.compile(r"`(repro(?:\.\w+)+(?:::[\w.]+)?)`")
    for f in docs:
        for m in mod_re.finditer(f.read_text()):
            if not module_ref_resolves(m.group(1)):
                problems.append(f"{f.relative_to(ROOT)}: module ref "
                                f"`{m.group(1)}` does not resolve "
                                f"under src/")

    # 5) documented CLI flags exist in the script they are shown with
    for f in docs:
        check_cli_flags(f, problems)

    # 6) metric names in the observability catalog exist in the obs
    # package (repro.obs.metrics.CATALOG is the pinned vocabulary)
    obs_doc = ROOT / "docs" / "observability.md"
    if obs_doc.exists():
        obs_src = "\n".join(p.read_text() for p in sorted(
            (ROOT / "src" / "repro" / "obs").glob("*.py")))
        for m in re.finditer(r"`((?:serve|train)_[a-z0-9_]+)`",
                             obs_doc.read_text()):
            if m.group(1) not in obs_src:
                problems.append(
                    f"docs/observability.md: metric `{m.group(1)}` not "
                    f"found in src/repro/obs/")

    # 7) scenario suites validate and are documented
    sys.path.insert(0, str(ROOT / "src"))
    from repro.fleet.scenarios import load_scenario_paths, \
        validate_scenario
    import json
    scen_paths = load_scenario_paths(ROOT / "benchmarks" / "scenarios")
    if not scen_paths:
        problems.append("benchmarks/scenarios/ has no scenario files")
    for p in scen_paths:
        doc = json.loads(p.read_text())
        for issue in validate_scenario(doc):
            problems.append(
                f"{p.relative_to(ROOT)}: invalid scenario — {issue}")
        name = doc.get("name", "")
        if name and name not in corpus:
            problems.append(
                f"scenario `{name}` ({p.relative_to(ROOT)}) is not "
                f"documented in README.md or docs/")

    if problems:
        print("docs-check FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"docs-check OK: {len(docs)} docs, all packages mentioned, "
          f"all links, module refs and CLI flags resolve, "
          f"{len(scen_paths)} scenario suites validate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
