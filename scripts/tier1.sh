#!/usr/bin/env sh
# Tier-1 verify (see ROADMAP.md). Runs everywhere: the test suite ships a
# deterministic fallback for hypothesis (tests/optional_deps.py), so no
# extra dependencies are required.
set -e
cd "$(dirname "$0")/.."
# docs drift nags but never blocks the test gate
python scripts/docs_check.py || echo "(docs-check failed; non-fatal)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# kernel-routing gate: every paged serving path through the Pallas
# kernels (interpret mode, fp + int8) must match the jnp oracle engine
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serve.py --smoke
