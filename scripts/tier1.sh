#!/usr/bin/env sh
# Tier-1 verify (see ROADMAP.md). Runs everywhere: the test suite ships a
# deterministic fallback for hypothesis (tests/optional_deps.py), so no
# extra dependencies are required.
set -e
cd "$(dirname "$0")/.."
# docs gate: every package documented, every link/module/CLI-flag
# reference resolves against the tree (fatal since PR 5)
python scripts/docs_check.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# kernel-routing gate: every paged serving path through the Pallas
# kernels (interpret mode, fp + int8) must match the jnp oracle engine;
# also runs the sharded-parity subprocess (8 forced devices): (2,2)-mesh
# and disaggregated engines must be token-identical to single-host
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_serve.py --smoke
# fleet gate: deterministic elastic scenario — the re-scale arm must
# beat queue-only goodput on the same failure trace, the simulated
# checkpoint-interval optimum must match the closed-form search — plus
# the serve-scenario arm: the autoscale-beats-static and
# burst-SLO-violation scenario suites (benchmarks/scenarios/) must pass
# their expect checks, a double-run must be byte-identical, and
# serve_calibration_check must recover a synthetic service law
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/bench_fleet.py --smoke
# trace gate: serve a short arrivals trace with telemetry on, then
# validate the Chrome trace (balanced spans, non-negative durations),
# replay the measured steptrace through the fleet simulator, merge
# serve + train + fleet events into one validating timeline, and hold
# the serve calibration gate: a saturated one-replica serve sim
# calibrated from the measured steptrace must reproduce the engine's
# per-chunk decode time within tolerance
TRACE_TMP="$(mktemp -d)"
trap 'rm -rf "$TRACE_TMP"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --arch qwen2_0_5b --smoke --trace 6 \
    --max-batch 2 --chunk 4 \
    --trace-out "$TRACE_TMP/serve_trace.json" \
    --metrics-out "$TRACE_TMP/serve_metrics.jsonl" \
    --steptrace-out "$TRACE_TMP/serve_steptrace.json"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/trace_gate.py "$TRACE_TMP/serve_trace.json" \
    "$TRACE_TMP/serve_steptrace.json"
