#!/usr/bin/env python
"""Tier-1 trace-validation gate (fatal).

Takes the Chrome trace + steptrace that the tier-1 serve smoke run just
wrote, then closes the whole telemetry loop in-process:

  1. validate the serve trace (balanced B/E per lane, non-negative
     durations, ``serve`` spans present);
  2. run a tiny ResilientTrainer with an enabled tracer so step / ckpt /
     replay spans land in the same schema;
  3. feed the *measured* serve steptrace through
     ``StepTimeModel.from_trace`` and drive a FleetSimulator off it,
     with the sim recording into the SAME tracer as the trainer;
  4. merge everything into one timeline and require the ``serve``,
     ``train`` and ``fleet`` categories to validate together — the
     ISSUE's "one Chrome trace can contain all three" acceptance;
  5. calibrate a ``ServiceTimeModel`` from the same measured steptrace
     and require ``serve_calibration_check`` to hold: a saturated
     one-replica serve sim must reproduce the engine's per-chunk decode
     time within tolerance (the serve-side bridge).

  PYTHONPATH=src python scripts/trace_gate.py TRACE.json STEPTRACE.json

Exit status is the number of failing stages (0 == gate passes).
"""

from __future__ import annotations

import json
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs.registry import get_smoke
from repro.fleet.bridge import serve_calibration_check
from repro.fleet.perf import StepTimeModel, job_spec_from_trace
from repro.fleet.sim import FleetConfig, FleetSimulator
from repro.launch.train import build_trainer
from repro.obs.steptrace import StepTrace
from repro.obs.trace import (SpanTracer, merge_chrome_traces,
                             validate_chrome_trace)
from repro.resilience.driver import StragglerPolicy


def check(label: str, problems: list) -> int:
    if problems:
        print(f"FAILED [{label}]:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"ok [{label}]")
    return 0


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    trace_path, steptrace_path = sys.argv[1], sys.argv[2]
    failures = 0

    # 1. the serve smoke run's request-lifecycle trace ----------------------
    with open(trace_path) as f:
        serve_doc = json.load(f)
    failures += check("serve trace", validate_chrome_trace(
        serve_doc, require_cats=["serve"]))

    # 2. tiny real trainer sharing one tracer with the sim ------------------
    shared = SpanTracer()
    tmp = tempfile.mkdtemp(prefix="trace_gate_")
    try:
        trainer, state = build_trainer(
            get_smoke("qwen2_0_5b"), batch=2, seq=16, ckpt_dir=tmp,
            checkpoint_every=4, failures={5: 0}, tracer=shared)
        trainer.straggler = StragglerPolicy(threshold=float("inf"))
        trainer.run(state, 8)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # 3. fleet sim driven by the MEASURED serve steptrace -------------------
    st = StepTrace.read(steptrace_path)
    model = StepTimeModel.from_trace(st)
    spec = job_spec_from_trace("measured", st, chips=64,
                               total_steps=24, checkpoint_every_steps=8)
    sim = FleetSimulator(
        FleetConfig(tpu="ironwood", total_cubes=2, host_mtbf_hours=None),
        [spec], tracer=shared)
    sim.run(100.0 * max(model.mean_step_s, 1e-3) * spec.total_steps + 10.0)
    job = sim.jobs["measured"]
    failures += check("steptrace-driven sim", [] if job.state == "done"
                      else [f"sim job state {job.state!r}, wanted 'done' "
                            f"(model mean {model.mean_step_s:.4f}s over "
                            f"{len(model.durations)} measured chunks)"])
    print(f"  measured step model: {model.mean_step_s * 1e3:.1f}ms mean "
          f"over {len(model.durations)} chunks -> sim goodput "
          f"{job.ledger.goodput:.4f}")

    # 4. one timeline: serve + train + fleet --------------------------------
    merged = merge_chrome_traces([serve_doc, shared.chrome_trace()])
    failures += check("merged serve+train+fleet timeline",
                      validate_chrome_trace(
                          merged, require_cats=["serve", "train", "fleet"]))

    # 5. serve-side bridge: sim service times vs the measured trace ---------
    cal = serve_calibration_check(st)
    failures += check("serve calibration", [] if cal["ok"] == 1.0 else [
        f"sim per-chunk {cal['sim_chunk_s'] * 1e3:.2f}ms vs measured "
        f"{cal['measured_chunk_s'] * 1e3:.2f}ms (rel_err "
        f"{cal['rel_err']:.3f}, {cal['steady_admissions']:.0f} steady "
        f"admissions at batch {cal['target_batch']:.0f})"])
    print(f"  calibrated service model: rel_err {cal['rel_err']:.2e} "
          f"over {cal['steady_admissions']:.0f} admissions at batch "
          f"{cal['target_batch']:.0f}")

    print("trace gate:", "FAILED" if failures else "PASSED")
    return failures


if __name__ == "__main__":
    sys.exit(main())
