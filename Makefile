# Convenience entry points. `make test` is the tier-1 gate from ROADMAP.md.

.PHONY: test test-serve test-fleet bench-serve bench-fleet serve-demo \
	fleet-demo docs-check

test:
	./scripts/tier1.sh

docs-check:
	python scripts/docs_check.py

test-serve:
	./scripts/tier1.sh tests/test_serve.py

test-fleet:
	./scripts/tier1.sh tests/test_fleet.py

bench-serve:
	PYTHONPATH=src python benchmarks/bench_serve.py

bench-fleet:
	PYTHONPATH=src python -m benchmarks.run --only fleet --json

serve-demo:
	PYTHONPATH=src python examples/serve_decode.py

fleet-demo:
	PYTHONPATH=src python examples/fleet_week.py
