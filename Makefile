# Convenience entry points. `make test` is the tier-1 gate from ROADMAP.md.

.PHONY: test test-serve bench-serve serve-demo

test:
	./scripts/tier1.sh

test-serve:
	./scripts/tier1.sh tests/test_serve.py

bench-serve:
	PYTHONPATH=src python benchmarks/bench_serve.py

serve-demo:
	PYTHONPATH=src python examples/serve_decode.py
