"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: instantiate the SMOKE config, run a forward + train
step on CPU, assert output shapes and finiteness; then verify that
prefill + single-token decode equals the full forward (exact KV/state
cache semantics) for every family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params, param_count

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


def make_batch(cfg, b, s, key):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.pos_emb == "mrope":
        p = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch["positions"] = jnp.stack([p, p, p])
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    batch = make_batch(cfg, 2, 16, jax.random.key(1))
    loss, metrics = api.loss_fn(params, batch, cfg, CTX)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(loss) > 0
    grads = jax.grad(lambda p: api.loss_fn(p, batch, cfg, CTX)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), \
        f"{arch} grads not finite"
    # at least half the leaves should receive nonzero gradient
    nonzero = sum(bool(jnp.any(g != 0)) for g in leaves)
    assert nonzero > len(leaves) // 2


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_consistency(arch):
    cfg = get_smoke(arch)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    b, s = 2, 12
    w = 16 if cfg.sliding_window is None else cfg.sliding_window
    key = jax.random.key(2)
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :s]}
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    _, cache = api.prefill_fn(params, batch, cfg, CTX, window=w)
    logits_dec, _ = api.decode_fn(params, toks[:, s:s + 1], cache, cfg, CTX)
    full = dict(batch)
    full["tokens"] = toks
    logits_ref, _ = api.prefill_fn(params, full, cfg, CTX, window=w + 8)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_ref),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_counts(arch):
    """Full configs match their published parameter counts (name-encoded)."""
    from repro.configs.registry import get_arch
    cfg = get_arch(arch)
    expected = {
        "whisper_small": (0.24e9, 0.35e9),
        "kimi_k2_1t_a32b": (0.95e12, 1.10e12),
        "mixtral_8x22b": (135e9, 145e9),
        "jamba_v01_52b": (49e9, 54e9),
        "qwen2_vl_7b": (7.0e9, 8.4e9),
        "internlm2_1_8b": (1.7e9, 2.0e9),
        "qwen2_0_5b": (0.45e9, 0.55e9),
        "phi4_mini_3_8b": (3.6e9, 4.0e9),
        "qwen2_5_3b": (2.9e9, 3.3e9),
        "rwkv6_1_6b": (1.4e9, 1.7e9),
    }[arch]
    n = cfg.total_params()
    assert expected[0] <= n <= expected[1], f"{arch}: {n:.3e}"
    # spec tree must agree with the analytic count within 2%
    spec_n = param_count(api.model_specs(cfg))
    assert abs(spec_n - n) / n < 0.02, (spec_n, n)


def test_mrope_reduces_to_rope_for_text():
    """With identical position streams, M-RoPE == RoPE (paper of record:
    Qwen2-VL); checked via the qwen2-vl smoke config vs a rope clone."""
    import dataclasses
    cfg = get_smoke("qwen2_vl_7b")
    cfg_rope = dataclasses.replace(cfg, pos_emb="rope", mrope_sections=())
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    batch = make_batch(cfg, 2, 8, jax.random.key(3))
    loss_m, _ = api.loss_fn(params, batch, cfg, CTX)
    batch.pop("positions")
    loss_r, _ = api.loss_fn(params, batch, cfg_rope, CTX)
    np.testing.assert_allclose(float(loss_m), float(loss_r), rtol=1e-6)


def test_moe_drops_tokens_when_capacity_exceeded():
    from repro.models.moe import moe_ffn, moe_param_specs
    import dataclasses
    cfg = dataclasses.replace(
        get_smoke("mixtral_8x22b"), capacity_factor=0.25)
    params = init_params(jax.random.key(0),
                         {"mlp": moe_param_specs(cfg)})["mlp"]
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, aux = moe_ffn(params, x, cfg, jnp.float32)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux["load_balance"]) > 0
