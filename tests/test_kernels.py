"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""

from optional_deps import hypothesis, st  # real or deterministic shim
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def rnd(i, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize("m,k,n", [(256, 512, 256), (512, 1024, 128),
                                   (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_matmul_matches_ref(m, k, n, dtype):
    a, b = rnd(1, (m, k), dtype), rnd(2, (k, n), dtype)
    out = ops.matmul(a, b, impl="interpret", out_dtype=jnp.float32,
                     block_m=128, block_n=128, block_k=128)
    want = ref.matmul_ref(a, b, jnp.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol * 8)


def test_matmul_fp8_storage():
    a = rnd(3, (128, 256)).astype(jnp.float8_e4m3fn).astype(jnp.bfloat16)
    b = rnd(4, (256, 128)).astype(jnp.float8_e4m3fn).astype(jnp.bfloat16)
    out = ops.matmul(a, b, impl="interpret", out_dtype=jnp.float32,
                     block_m=128, block_n=128, block_k=128)
    want = ref.matmul_ref(a, b, jnp.float32)
    np.testing.assert_allclose(out, want, rtol=1e-2, atol=0.5)


# ------------------------------------------------------- flash attention


@pytest.mark.parametrize("bh,s,d", [(4, 256, 64), (2, 128, 112),
                                    (1, 512, 64)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_matches_ref(bh, s, d, causal, window):
    q, k, v = (rnd(i, (bh, s, d), jnp.bfloat16) for i in (5, 6, 7))
    out = ops.flash_attention(q, k, v, impl="interpret", causal=causal,
                              window=window, block_q=128, block_k=128)
    want = ops.flash_attention(q, k, v, impl="ref", causal=causal,
                               window=window)
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32),
        rtol=2e-2, atol=2e-2)


def test_flash_attention_causality():
    """Changing future keys must not change past outputs."""
    q, k, v = (rnd(i, (2, 256, 64)) for i in (8, 9, 10))
    out1 = ops.flash_attention(q, k, v, impl="interpret")
    k2 = k.at[:, 200:].set(99.0)
    v2 = v.at[:, 200:].set(-99.0)
    out2 = ops.flash_attention(q, k2, v2, impl="interpret")
    np.testing.assert_allclose(out1[:, :200], out2[:, :200],
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- decode attention


@pytest.mark.parametrize("h,kv", [(8, 2), (4, 4), (16, 8)])
@pytest.mark.parametrize("pos", [5, 128, 200])
def test_decode_attention_matches_ref(h, kv, pos):
    b, d, w = 2, 64, 128
    q = rnd(11, (b, h, d))
    kc, vc = rnd(12, (b, w, kv, d)), rnd(13, (b, w, kv, d))
    p = jnp.full((b,), pos, jnp.int32)
    out = ops.decode_attention(q, kc, vc, p, impl="interpret", block_k=64)
    want = ops.decode_attention(q, kc, vc, p, impl="ref")
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_decode_attention_sliding_window():
    b, h, kv, d, w = 1, 4, 2, 32, 64
    q = rnd(14, (b, h, d))
    kc, vc = rnd(15, (b, w, kv, d)), rnd(16, (b, w, kv, d))
    p = jnp.full((b,), 64, jnp.int32)
    out = ops.decode_attention(q, kc, vc, p, impl="interpret",
                               window=16, block_k=32)
    want = ops.decode_attention(q, kc, vc, p, impl="ref", window=16)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- rwkv


@pytest.mark.parametrize("s,hd,chunk", [(64, 16, 16), (128, 32, 16),
                                        (48, 16, 8)])
def test_rwkv_wkv_matches_serial_ref(s, hd, chunk):
    bh = 3
    r, k, v = (rnd(i, (bh, s, hd)) for i in (17, 18, 19))
    lw = jnp.clip(-jnp.exp(rnd(20, (bh, s, hd))), -4.0, 0.0)
    u = rnd(21, (bh, hd)) * 0.5
    out = ops.rwkv_wkv(r, k, v, lw, u, impl="interpret", chunk=chunk)
    want = ops.rwkv_wkv(r, k, v, lw, u, impl="ref")
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


@hypothesis.given(
    decay=st.floats(min_value=-4.0, max_value=-0.01),
    s=st.sampled_from([16, 32, 64]),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_rwkv_constant_decay_is_ema(decay, s):
    """With constant decay, r=e_i, k=e_i the WKV reduces to a 1-channel
    exponentially weighted sum — closed form check."""
    hd = 8
    r = jnp.zeros((1, s, hd)).at[:, :, 0].set(1.0)
    k = jnp.zeros((1, s, hd)).at[:, :, 0].set(1.0)
    v = jnp.ones((1, s, hd))
    lw = jnp.full((1, s, hd), decay)
    u = jnp.zeros((1, hd))
    out = np.asarray(ops.rwkv_wkv(r, k, v, lw, u, impl="ref"))
    # out_t = sum_{j<t} exp(decay*(t-1-j)) ... geometric series
    t = np.arange(s)
    w = np.exp(decay)
    expected = (1 - w**t) / (1 - w)
    np.testing.assert_allclose(out[0, :, 0], expected, rtol=1e-4, atol=1e-4)


def test_rwkv_chunked_kernel_invariant_to_chunk_size():
    bh, s, hd = 2, 96, 16
    r, k, v = (rnd(i, (bh, s, hd)) for i in (22, 23, 24))
    lw = jnp.clip(-jnp.exp(rnd(25, (bh, s, hd))), -4.0, 0.0)
    u = rnd(26, (bh, hd)) * 0.5
    a = ops.rwkv_wkv(r, k, v, lw, u, impl="interpret", chunk=8)
    b = ops.rwkv_wkv(r, k, v, lw, u, impl="interpret", chunk=16)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------- sparse gather


@pytest.mark.parametrize("v,d,n,bag", [(128, 64, 16, 4), (512, 128, 8, 8)])
def test_sparse_gather_matches_ref(v, d, n, bag):
    tbl = rnd(27, (v, d))
    idx = jax.random.randint(jax.random.fold_in(KEY, 28), (n, bag), 0, v)
    w = rnd(29, (n, bag))
    out = ops.sparse_gather_sum(tbl, idx, w, impl="interpret")
    want = ops.sparse_gather_sum(tbl, idx, w, impl="ref")
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@hypothesis.given(st.integers(min_value=0, max_value=126))
@hypothesis.settings(max_examples=8, deadline=None)
def test_sparse_gather_one_hot(i):
    """bag of one index with weight 1 == that table row."""
    tbl = rnd(30, (127, 32))
    idx = jnp.full((1, 1), i, jnp.int32)
    w = jnp.ones((1, 1))
    out = ops.sparse_gather_sum(tbl, idx, w, impl="interpret")
    np.testing.assert_allclose(out[0], tbl[i], rtol=1e-6, atol=1e-6)


# --------------------------------------------------------- grouped GEMM


@pytest.mark.parametrize("block_m,d,f", [(8, 32, 64), (16, 64, 128)])
def test_grouped_matmul_matches_ref(block_m, d, f):
    gids = jnp.array([0, 0, 1, -1, 2, 3, 3, -1], jnp.int32)
    x = rnd(40, (gids.shape[0] * block_m, d))
    w = rnd(41, (4, d, f))
    out = ops.grouped_matmul(x, w, gids, impl="interpret", block_f=f)
    want = ops.grouped_matmul(x, w, gids, impl="ref")
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@hypothesis.given(st.integers(min_value=0, max_value=3))
@hypothesis.settings(max_examples=4, deadline=None)
def test_grouped_matmul_single_tile_is_plain_matmul(e):
    """one m-tile routed to expert e == x @ w[e]."""
    x = rnd(42, (16, 32))
    w = rnd(43, (4, 32, 64))
    gids = jnp.full((1,), e, jnp.int32)
    out = ops.grouped_matmul(x, w, gids, impl="interpret", block_f=64)
    np.testing.assert_allclose(out, x @ w[e], rtol=1e-4, atol=1e-4)
