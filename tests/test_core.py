"""Core paper-contribution modules: Table-1 claims, topology, OCS scheduler
invariants (hypothesis), goodput, CCI relations, SDC detection."""

from optional_deps import hypothesis, st  # real or deterministic shim
import numpy as np
import pytest

from repro.core import cci, hwspec
from repro.core.goodput import GoodputLedger, modeled_goodput
from repro.core.ocs import CUBE, OCSPodScheduler, slice_availability
from repro.core.topology import Torus, cube_grid, slice_torus


def test_table1_bisection_matches_paper():
    claims = {"tpu_v2": 1984, "tpu_v3": 4480, "tpu_v4": 25600,
              "tpu_v5p": 64000, "ironwood": 76800}
    for spec in hwspec.GENERATIONS:
        assert spec.pod_bisection_gbps == pytest.approx(
            claims[spec.name], rel=1e-3), spec.name


def test_scaling_headlines():
    s = hwspec.scaling_summary()
    assert s["pod_size_x"] == 36.0
    assert 3500 < s["pod_peak_x"] < 3700  # "~3600x"
    assert 95 < s["node_peak_x"] < 105  # "~100x"
    assert 400 < s["pod_hbm_x"] < 450  # "~400x"


def test_mxu_flops_consistency():
    # peak TFLOPS should be explained by MXU count x size x 2 x clock
    v4 = hwspec.TPU_V4
    assert v4.matmul_peak_flops_per_cycle() == 8 * 2 * 128 * 128
    iw = hwspec.IRONWOOD
    # Table 1: 4x 256x256 bf16 + 4x 512x512 fp8 arrays -> 4x the MACs per
    # cycle, yet the peak TFLOPS ratio is 2x (the paper's numbers; the fp8
    # arrays evidently don't clock all lanes every cycle).
    assert iw.matmul_peak_flops_per_cycle("fp8") == \
        4 * iw.matmul_peak_flops_per_cycle("bf16")
    assert iw.peak_fp8_tflops == 2 * iw.peak_bf16_tflops


def test_torus_bisection_and_links():
    t = Torus((16, 16), 62.0)
    assert t.num_nodes == 256
    assert t.links_per_node == 4
    assert t.bisection_gbps() == 1984.0
    t3 = Torus((16, 24, 24), 100.0)
    assert t3.links_per_node == 6
    assert t3.bisection_gbps() == 76800.0


def test_cube_geometry():
    assert CUBE.chips == 64
    assert CUBE.optical_links == 96
    assert CUBE.ocses_per_cube == 48
    assert cube_grid(2048) == (2, 4, 4)  # 32 cubes, balanced


def test_ring_allreduce_time_sane():
    t = Torus((16,), 50.0)
    # 1 GiB per node, bidirectional ring: 2*(15/16)*1GiB / 100GB/s
    dt = t.ring_allreduce_time(2**30, 0)
    assert dt == pytest.approx(2 * 15 / 16 * 2**30 / 100e9, rel=1e-6)


# ---------------------------------------------------------------- OCS


@hypothesis.given(
    jobs=st.lists(st.integers(min_value=1, max_value=40), min_size=1,
                  max_size=8),
    failures=st.lists(st.integers(min_value=0, max_value=143), max_size=10),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_ocs_never_double_allocates(jobs, failures):
    sched = OCSPodScheduler(144)
    allocated = {}
    for i, cubes in enumerate(jobs):
        alloc = sched.allocate(f"j{i}", cubes * CUBE.chips)
        if alloc is not None:
            allocated[f"j{i}"] = alloc
    for c in failures:
        job = sched.fail_cube(c)
        if job is not None and job in allocated:
            patched = sched.substitute(job)
            if patched is not None:
                allocated[job] = patched
    # invariant: no cube owned by two jobs; no failed cube in an allocation
    seen = {}
    for job, alloc in sched.allocations.items():
        assert len(set(alloc.cubes)) == len(alloc.cubes)
        for c in alloc.cubes:
            assert c not in seen, f"cube {c} in {job} and {seen[c]}"
            seen[c] = job
    # a substituted allocation never contains a failed cube
    for job, alloc in sched.allocations.items():
        broken = set(alloc.cubes) & sched.failed_cubes
        if broken:  # only possible when substitution failed (no spares)
            assert sched.spare_cubes() < len(broken)


def test_ocs_substitution_preserves_volume():
    sched = OCSPodScheduler(144)
    alloc = sched.allocate("a", 2048)
    assert alloc is not None and len(alloc.cubes) == 32
    victim = alloc.cubes[5]
    assert sched.fail_cube(victim) == "a"
    patched = sched.substitute("a")
    assert patched is not None
    assert len(patched.cubes) == 32
    assert victim not in patched.cubes
    assert patched.torus_dims == alloc.torus_dims


def test_contiguous_mode_is_harder():
    free = OCSPodScheduler(64, contiguous=False)
    hard = OCSPodScheduler(64, contiguous=True)
    # fragment: fail a scattered pattern of cubes
    for c in range(0, 64, 9):
        free.fail_cube(c)
        hard.fail_cube(c)
    assert free.allocate("x", 16 * 64) is not None
    # the contiguous scheduler may or may not fit a 16-cube block; at
    # minimum it can never succeed when OCS fails
    if hard.allocate("x", 16 * 64) is not None:
        assert free.spare_cubes() >= 0


def test_slice_availability():
    # paper: Ironwood pod = 2304 hosts; 99.9% host avail -> ~10% pod avail
    a = slice_availability(0.999, 9216)
    assert 0.05 < a < 0.15
    assert slice_availability(1.0, 9216) == 1.0


# ------------------------------------------------------------- goodput


def test_goodput_ledger():
    led = GoodputLedger()
    led.record_steps(90.0, steps=90)
    led.record_detection(2.0)
    led.record_restore(3.0)
    led.record_rework(5.0, steps=5)
    assert led.goodput == pytest.approx(0.9)
    assert led.effective_steps == 90
    with pytest.raises(ValueError):
        led.record_steps(-1.0, steps=1)


def test_modeled_goodput_brackets_paper():
    g97 = modeled_goodput(mtbf_hours=24, detect_s=30, restore_s=120,
                          checkpoint_interval_s=600)
    g93 = modeled_goodput(mtbf_hours=4, detect_s=60, restore_s=300,
                          checkpoint_interval_s=900)
    assert g97 > 0.96
    assert 0.88 < g93 < 0.97


# ------------------------------------------------------------------ CCI


def test_cci_paper_relations():
    v4, v5p, iw = cci.CCI_TPU_V4, cci.CCI_TPU_V5P, cci.CCI_IRONWOOD
    assert v5p.total_market == pytest.approx(265, rel=0.02)
    assert v4.operational_market / v5p.operational_market == \
        pytest.approx(1.1, rel=0.05)
    assert v4.embodied / v5p.embodied == pytest.approx(1.3, rel=0.05)
    assert v5p.operational_market / iw.operational_market == \
        pytest.approx(3.7, rel=0.05)
    assert iw.embodied_share_location == pytest.approx(0.08, rel=0.15)


def test_cci_gpt3_example():
    grams = cci.emissions_grams(3.14e23, cci.CCI_TPU_V5P)
    assert grams == pytest.approx(8.3e7, rel=0.05)


def test_operational_cci_identity():
    # op CCI = EEF / perf-per-watt
    out = cci.operational_cci_from_perf_per_watt(
        electricity_gco2e_per_kwh=100.0, flops_per_watt=1e12)
    # 1e12 FLOP/s/W = 3.6e18 FLOP/kWh -> 100/3.6e18 g/FLOP = 27.8 g/EFLOP
    assert out == pytest.approx(27.8, rel=0.01)


def test_carbon_ledger():
    led = cci.CarbonLedger(cci.CCI_IRONWOOD)
    led.record_step(1e18)
    assert led.grams_co2e == pytest.approx(cci.CCI_IRONWOOD.total_market)
    with pytest.raises(ValueError):
        led.record_step(-1.0)
