"""The model's xla attention path and the Pallas flash-attention kernel
path (interpret mode) must agree end-to-end through a full model forward —
the kernel is a drop-in for the perf-critical layer, not a side artifact."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params


def test_flash_kernel_path_matches_xla_in_model():
    # 128-token sequence so the kernel path engages (128-aligned blocks)
    cfg = dataclasses.replace(get_smoke("qwen2_5_3b"))
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    b, s = 2, 128
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                           attn_impl=impl)
        outs[impl], _ = api.loss_fn(params, batch, cfg, ctx)
    np.testing.assert_allclose(float(outs["xla"]),
                               float(outs["pallas_interpret"]),
                               rtol=2e-5)


def test_flash_kernel_path_swa_model():
    cfg = dataclasses.replace(get_smoke("mixtral_8x22b"), sliding_window=64)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    b, s = 1, 128
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    vals = []
    for impl in ("xla", "pallas_interpret"):
        ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                           attn_impl=impl)
        loss, _ = api.loss_fn(params, batch, cfg, ctx)
        vals.append(float(loss))
    np.testing.assert_allclose(vals[0], vals[1], rtol=2e-5)
