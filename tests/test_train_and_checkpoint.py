"""Training substrate: determinism, microbatch equivalence, optimizer
behavior, checkpoint roundtrip/corruption/async, failure recovery."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke
from repro.core.sdc import FBIST, FaultModel, faulty_wrap
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch.train import build_trainer
from repro.models import api
from repro.models.blocks import ModelContext
from repro.optim.optimizers import adafactor, adamw, clip_by_global_norm, \
    cosine_schedule
from repro.train.step import TrainSettings, init_train_state, \
    make_train_step

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


def small_setup(arch="qwen2_0_5b", micro=1):
    cfg = get_smoke(arch)
    opt = adamw(cosine_schedule(1e-3, 10, 1000))
    step = jax.jit(make_train_step(cfg, CTX, opt,
                                   TrainSettings(microbatches=micro)))
    state = init_train_state(jax.random.key(0), cfg, opt)
    pipe = DataPipeline(DataConfig(global_batch=4, seq_len=32,
                                   vocab_size=cfg.vocab_size), cfg)
    return cfg, step, state, pipe


def to_jax(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


def test_loss_decreases():
    cfg, step, state, pipe = small_setup()
    losses = []
    for i in range(20):
        state, m = step(state, to_jax(pipe.batch_for_step(i)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses[:3] + losses[-3:]
    assert all(np.isfinite(losses))


def test_determinism_bitwise():
    """Same seed => bit-identical loss trajectory (paper's strict
    deterministic repeatability)."""
    traces = []
    for _ in range(2):
        cfg, step, state, pipe = small_setup()
        tr = []
        for i in range(5):
            state, m = step(state, to_jax(pipe.batch_for_step(i)))
            tr.append(float(m["loss"]))
        traces.append(tr)
    assert traces[0] == traces[1]


def test_microbatch_equivalence():
    """mb=1 and mb=4 give (nearly) the same gradient step."""
    _, step1, state1, pipe = small_setup(micro=1)
    _, step4, state4, _ = small_setup(micro=4)
    batch = to_jax(pipe.batch_for_step(0))
    s1, m1 = step1(state1, batch)
    s4, m4 = step4(state4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    l1 = jax.tree.leaves(s1["params"])
    l4 = jax.tree.leaves(s4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_grad_clip():
    grads = {"a": jnp.full((4,), 100.0), "b": jnp.full((3,), -100.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    total = sum(float(jnp.sum(g**2)) for g in jax.tree.leaves(clipped))
    assert total == pytest.approx(1.0, rel=1e-4)
    assert float(gn) == pytest.approx(np.sqrt(7 * 100.0**2), rel=1e-5)


def test_adafactor_factored_state_is_small():
    opt = adafactor(cosine_schedule(1e-3, 10, 1000))
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    assert set(state["w"]) == {"vr", "vc"}
    assert state["w"]["vr"].shape == (256,)
    assert state["w"]["vc"].shape == (512,)
    assert set(state["b"]) == {"v"}
    # a step moves params
    grads = {"w": jnp.ones((256, 512)), "b": jnp.ones((8,))}
    new_p, _ = opt.update(grads, state, params, jnp.asarray(5, jnp.int32))
    assert float(jnp.max(jnp.abs(new_p["w"]))) > 0


# ------------------------------------------------------------ checkpoint


def test_checkpoint_roundtrip_and_gc():
    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp, keep=2)
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "step": jnp.asarray(7)}
        for s in (1, 2, 3):
            mgr.save(s, state, blocking=True)
        assert mgr.all_steps() == [2, 3]  # gc kept 2
        out = mgr.restore(3, state)
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
    finally:
        shutil.rmtree(tmp)


def test_checkpoint_detects_corruption():
    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp)
        state = {"w": jnp.ones((8, 8))}
        mgr.save(1, state, blocking=True)
        # corrupt the leaf file
        leaf = os.path.join(tmp, "step_00000001", "w.npy")
        arr = np.load(leaf)
        arr[0, 0] = 999.0
        np.save(leaf, arr)
        with pytest.raises(IOError, match="checksum"):
            mgr.restore(1, state)
    finally:
        shutil.rmtree(tmp)


def test_checkpoint_corruption_falls_back_to_previous_step():
    """A corrupt latest checkpoint (truncated leaf file) must not kill
    the restore when an older complete checkpoint exists: the corrupt
    step is quarantined (renamed ``.corrupt``, invisible to all_steps)
    and the previous manifest restored, with ``last_restored_step``
    re-anchoring the caller's replay range."""
    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp, keep=3)
        for s in (1, 2):
            mgr.save(s, {"w": jnp.full((4, 4), float(s))}, blocking=True)
        leaf = os.path.join(tmp, "step_00000002", "w.npy")
        with open(leaf, "r+b") as fh:  # truncate mid-payload
            fh.truncate(os.path.getsize(leaf) // 2)
        out = mgr.restore(2, {"w": jnp.zeros((4, 4))})
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.full((4, 4), 1.0))
        assert mgr.last_restored_step == 1
        assert mgr.all_steps() == [1]
        assert os.path.isdir(os.path.join(tmp, "step_00000002.corrupt"))
        assert not os.path.isdir(os.path.join(tmp, "step_00000002"))
    finally:
        shutil.rmtree(tmp)


def test_checkpoint_async_and_shape_mismatch():
    tmp = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(tmp)
        state = {"w": jnp.ones((4, 4))}
        mgr.save(5, state)  # async
        mgr.wait()
        assert mgr.latest_step() == 5
        with pytest.raises(ValueError, match="shape"):
            mgr.restore(5, {"w": jnp.ones((2, 2))})
    finally:
        shutil.rmtree(tmp)


# -------------------------------------------------- failure recovery


def test_failure_recovery_matches_uninterrupted_run():
    """A run with an injected failure + restore must reproduce the exact
    loss trajectory of an uninterrupted run (determinism + checkpointing
    + replay = the paper's resilience contract)."""
    tmp1, tmp2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        cfg = get_smoke("internlm2_1_8b")
        tr1, st1 = build_trainer(cfg, batch=4, seq=32, ckpt_dir=tmp1,
                                 checkpoint_every=5)
        _, led1, losses1 = tr1.run(st1, 14)
        tr2, st2 = build_trainer(cfg, batch=4, seq=32, ckpt_dir=tmp2,
                                 checkpoint_every=5, failures={9: 3})
        _, led2, losses2 = tr2.run(st2, 14)
        assert losses1 == losses2
        assert led2.totals().get("rework", 0) > 0
        assert led2.goodput < 1.0
        assert led1.goodput > led2.goodput
    finally:
        shutil.rmtree(tmp1)
        shutil.rmtree(tmp2)


def test_fbist_catches_marginal_device_in_train_path():
    fb = FBIST(m=64, k=64, n=64, n_patterns=5)
    assert fb.run(lambda a, b: a @ b).passed
    bad = faulty_wrap(lambda a, b: a @ b,
                      FaultModel(rate=1.0, magnitude=0.5, seed=1))
    assert not fb.run(bad).passed


# --------------------------------------------------------------- data


def test_pipeline_deterministic_and_step_indexed():
    cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=101, seed=3)
    p1, p2 = DataPipeline(cfg), DataPipeline(cfg)
    b1, b2 = p1.batch_for_step(42), p2.batch_for_step(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_for_step(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    full1 = p1.batch_for_step(7)
    assert full1["tokens"].shape == (4, 16)
    assert (full1["tokens"] < 101).all()


def test_token_file_source():
    tmp = tempfile.mkdtemp()
    try:
        path = os.path.join(tmp, "tokens.bin")
        np.arange(4 * 17 * 3, dtype=np.int32).tofile(path)
        cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=1 << 30,
                         token_file=path)
        pipe = DataPipeline(cfg)
        b = pipe.batch_for_step(0)
        assert b["tokens"].shape == (4, 16)
        b2 = pipe.batch_for_step(0)
        np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    finally:
        shutil.rmtree(tmp)
