"""Sharded serving: mesh-parallel paged decode parity, GQA KV-replication
fallback, prefill/decode disaggregation, mrope through the span paths,
and the dropped-rule report.

Mesh tests run in the ``subproc`` fixture (jax locks the device count at
first init, so anything needing > 1 device gets a fresh process with
forced fake host devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import PageTransferModel, ServeEngine
from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                   PrefillWorkerPool, Request)
from repro.sharding.axes import RULE_SETS, summarize_dropped

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


# --------------------------------------------------- mesh parity (subproc)


def test_sharded_decode_matches_single_host(subproc):
    """(4, 2) mesh (true tensor parallelism: kv=2 divides model=2) must be
    token-identical to the single-host engine — f32, bf16 and int8 pools,
    with speculation on and a second run decoding off prefix-cache
    hits."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request

cfg = get_smoke("qwen2_0_5b")
params = init_params(jax.random.key(0), api.model_specs(cfg))
rng = np.random.default_rng(1)
ps = [rng.integers(0, cfg.vocab_size, int(rng.integers(8, 15)))
      for _ in range(4)]
reqs = lambda: [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(ps)]
mesh = jax.make_mesh((4, 2), ("data", "model"))
for cdt in (None, jnp.bfloat16, jnp.int8):
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                       decode_cache_dtype=cdt)
    solo = ServeEngine(cfg, ctx, window=48, max_batch=2, chunk=4,
                       page_size=8, draft_k=2)
    shard = ServeEngine(cfg, ctx, window=48, max_batch=2, chunk=4,
                        page_size=8, draft_k=2, mesh=mesh)
    assert shard.sharding_report["mesh"] == {"data": 4, "model": 2}
    for r in range(2):
        so, sh = solo.run(params, reqs()), shard.run(params, reqs())
        for i in range(4):
            np.testing.assert_array_equal(so[i], sh[i])
    assert shard.prefix_hit_rate > 0, "run 2 must hit the prefix cache"
print("SHARDED-PARITY-OK")
""", devices=8)
    assert "SHARDED-PARITY-OK" in out


def test_gqa_kv_fallback_sharded_parity(subproc):
    """mixtral smoke (h=8, kv=2) on a (2, 4) mesh: kv does not divide
    model=4, so the KV pool replicates (dropped rule reported) and each
    shard slices its local groups — output still token-identical, SWA
    page trimming included."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request

cfg = get_smoke("mixtral_8x22b")
assert cfg.n_heads == 8 and cfg.n_kv_heads == 2
params = init_params(jax.random.key(0), api.model_specs(cfg))
ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64)
rng = np.random.default_rng(2)
ps = [rng.integers(0, cfg.vocab_size, int(rng.integers(6, 12)))
      for _ in range(3)]
reqs = lambda: [Request(rid=i, prompt=p, max_new=6)
                for i, p in enumerate(ps)]
solo = ServeEngine(cfg, ctx, window=32, max_batch=2, chunk=4, page_size=4)
mesh = jax.make_mesh((2, 4), ("data", "model"))
shard = ServeEngine(cfg, ctx, window=32, max_batch=2, chunk=4,
                    page_size=4, mesh=mesh)
drops = " ; ".join(shard.sharding_report["dropped_rules"])
assert "kv_heads=2" in drops, drops
so, sh = solo.run(params, reqs()), shard.run(params, reqs())
for i in range(3):
    np.testing.assert_array_equal(so[i], sh[i])
print("GQA-FALLBACK-OK")
""", devices=8)
    assert "GQA-FALLBACK-OK" in out


# ------------------------------------------------------- disaggregation


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


def _reqs(cfg, n=4, seed=1, max_new=8, arrivals=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(8, 15))),
                    max_new=max_new,
                    arrival=0 if arrivals is None else arrivals[i])
            for i in range(n)]


def test_disaggregated_matches_colocated(qwen):
    """Disaggregated greedy output == co-located, on both modeled links,
    with nonzero transfer traffic and per-role queue-depth stats."""
    cfg, params = qwen
    co = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                     page_size=8)
    want = co.run(params, _reqs(cfg))
    for link in ("ici", "dcn"):
        dis = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                          page_size=8, disaggregate=True,
                          prefill_workers=2, transfer_link=link)
        got = dis.run(params, _reqs(cfg))
        for i in range(4):
            np.testing.assert_array_equal(want[i], got[i])
        ts = dis.transfer_stats()
        assert ts["link"] == link
        assert ts["transfers"] == 4
        assert ts["transfer_bytes"] > 0
        assert ts["transfer_stall_boundaries"] >= 1
        assert ts["prefill_depth_peak"] >= 1
        assert dis.prefill_pool.stats["placed"] == 4


def test_disaggregated_with_speculation_and_arrivals(qwen):
    """Parked-slot freezing composes with spec decode and staggered
    arrivals: the frozen slot's span writes are idempotent, so delayed
    activation stays token-identical."""
    cfg, params = qwen
    arrivals = [0, 3, 9, 9]
    co = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                     page_size=8, draft_k=2)
    want = co.run(params, _reqs(cfg, arrivals=arrivals))
    dis = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                      page_size=8, draft_k=2, disaggregate=True)
    got = dis.run(params, _reqs(cfg, arrivals=arrivals))
    for i in range(4):
        np.testing.assert_array_equal(want[i], got[i])


def test_disaggregate_requires_paged():
    cfg = get_smoke("rwkv6_1_6b")
    with pytest.raises(ValueError, match="disaggregation requires"):
        ServeEngine(cfg, CTX, window=32, max_batch=2, disaggregate=True)


def test_transfer_model_scales_with_link():
    """DCN pays more latency and less bandwidth than ICI for the same
    pages, so its transfers span at least as many decode boundaries."""
    mk = lambda link: PageTransferModel(page_bytes=1 << 14, chunk=8,
                                        resident_bytes=1 << 22, link=link)
    ici, dcn = mk("ici"), mk("dcn")
    assert dcn.transfer_s(4) > ici.transfer_s(4)
    assert dcn.delay_boundaries(4) >= ici.delay_boundaries(4)
    assert ici.delay_boundaries(1) >= 1
    with pytest.raises(ValueError, match="transfer link"):
        mk("rdma")


def test_prefill_worker_pool_queueing():
    """Least-loaded placement, FIFO readiness, prefill_done lifecycle
    (set by pop_ready, reset by preemption)."""
    pool = PrefillWorkerPool(2, span_len=4, chunk=4)
    rs = [Request(rid=i, prompt=np.arange(6), max_new=2) for i in range(3)]
    for r in rs:
        pool.place(r, clock=0)  # 6 tokens -> 2 spans -> 8 clock units
    assert pool.depths() == [2, 1]  # third request joins the shallower q
    assert pool.pending()
    assert pool.pop_ready(0) == []
    ready = pool.pop_ready(8)
    assert [r.rid for r in ready] == [0, 1]  # heads of both queues
    assert all(r.prefill_done for r in ready)
    assert pool.pop_ready(100) == [rs[2]]  # queued behind rid 0
    assert not pool.pending()
    sched = ContinuousBatchingScheduler(2)
    sched.add(rs[0])
    sched.admit(rs[0], 0)
    sched.preempt(rs[0])
    assert not rs[0].prefill_done, "preemption must force re-prefill"


# ------------------------------------------------------------- mrope


def _vl_positions(b, s):
    """Vision-style (3, B, S) rows: a 4-token 2x2 image patch block
    (temporal/height/width rows differ) then a text tail — laid out so
    max(positions) == s - 1 and the text continuation both backends use
    for decode agrees."""
    t = [0, 0, 0, 0]
    h = [0, 0, 1, 1]
    w = [0, 1, 0, 1]
    tail = list(range(2, 2 + s - 4))
    pos = np.stack([t + tail, h + tail, w + tail]).astype(np.int32)
    return np.broadcast_to(pos[:, None, :], (3, b, s)).copy()


def test_mrope_chunked_prefill_matches_dense(qwen):
    """qwen2_vl rides the paged chunked span prefill with its explicit
    mrope rows: tokens must match the dense full-prompt oracle across
    chunk sizes (including a chunk size that splits the image block)."""
    cfg = get_smoke("qwen2_vl_7b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    rng = np.random.default_rng(3)
    s = 12
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, s)), jnp.int32),
        "positions": jnp.asarray(_vl_positions(2, s))}
    oracle = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4)
    want = oracle.generate_pertoken(params, batch, max_new=6)
    for prefill_chunk in (3, 5, 128):
        eng = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4,
                          page_size=8, prefill_chunk=prefill_chunk)
        assert eng.paged
        got = eng.generate(params, batch, max_new=6)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert eng.counters["prefill_span_calls"] >= (
            2 * -(-s // min(prefill_chunk, 32)))


def test_mrope_requests_bypass_prefix_cache():
    """Two requests with the SAME tokens but different position rows hold
    different KV: explicit-position admissions must neither publish nor
    adopt content-addressed prefix pages."""
    cfg = get_smoke("qwen2_vl_7b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    rng = np.random.default_rng(4)
    s = 12
    toks = rng.integers(0, cfg.vocab_size, (1, s))
    batch = {"tokens": jnp.asarray(toks, jnp.int32),
             "positions": jnp.asarray(_vl_positions(1, s))}
    text_pos = np.broadcast_to(np.arange(s, dtype=np.int32), (3, 1, s))
    batch_text = {"tokens": jnp.asarray(toks, jnp.int32),
                  "positions": jnp.asarray(text_pos.copy())}
    eng = ServeEngine(cfg, CTX, window=32, max_batch=1, chunk=4,
                      page_size=4)
    out_vl = eng.generate(params, batch, max_new=6)
    out_text = eng.generate(params, batch_text, max_new=6)
    assert eng.kv.counters["prefix_hit_tokens"] == 0
    assert eng.kv.counters["pages_published"] == 0
    # same tokens, different geometry -> different prefill logits (the
    # aliasing the bypass prevents); greedy argmax may still coincide on
    # the smoke model, so the check is at the logits level
    l_vl, _ = api.prefill_fn(params, batch, cfg, CTX, window=32)
    l_text, _ = api.prefill_fn(params, batch_text, cfg, CTX, window=32)
    assert np.abs(np.asarray(l_vl) - np.asarray(l_text)).max() > 1e-6
    # oracle agreement for both runs
    for b, out in ((batch, out_vl), (batch_text, out_text)):
        want = eng.generate_pertoken(params, b, max_new=6)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_mrope_dense_chunked_prefill():
    """The dense span path threads mrope too: force qwen2_vl onto the
    dense backend and check chunked == full-prompt oracle."""
    cfg = get_smoke("qwen2_vl_7b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    rng = np.random.default_rng(5)
    s = 10
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32),
        "positions": jnp.asarray(_vl_positions(1, s))}
    eng = ServeEngine(cfg, CTX, window=24, max_batch=1, chunk=4,
                      paged=False, prefill_chunk=4)
    assert eng.chunk_prefill
    want = eng.generate_pertoken(params, batch, max_new=5)
    got = eng.generate(params, batch, max_new=5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert eng.counters["prefill_span_calls"] >= 2


# ------------------------------------------------- dropped-rule reporting


def test_summarize_dropped_renders_fallbacks():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lines = summarize_dropped([("kv_heads", 2), ("kv_heads", 2),
                               ("vocab", 211)],
                              mesh, RULE_SETS["baseline_dp_tp"])
    assert len(lines) == 2  # deduped
    assert "kv_heads=2" in lines[0] and "replicated" in lines[0]
    assert "vocab=211" in lines[1]


def test_engine_reports_dropped_rules_once(subproc):
    """dropped_rules is populated at construction (KV pool placement) and
    extended by shard_params, without duplicate lines."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import ServeEngine

cfg = get_smoke("mixtral_8x22b")
params = init_params(jax.random.key(0), api.model_specs(cfg))
ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64)
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng = ServeEngine(cfg, ctx, window=32, max_batch=2, chunk=4, page_size=4,
                  mesh=mesh)
before = list(eng.sharding_report["dropped_rules"])
assert any("kv_heads=2" in ln for ln in before), before
eng.shard_params(params)
after = eng.sharding_report["dropped_rules"]
assert len(after) == len(set(after)), "duplicate fallback lines"
assert set(before) <= set(after)
single = ServeEngine(cfg, ctx, window=32, max_batch=2, chunk=4,
                     page_size=4)
assert single.sharding_report == {"mesh": None, "rules": "baseline_dp_tp",
                                  "dropped_rules": []}
print("DROPPED-REPORT-OK")
""", devices=8)
    assert "DROPPED-REPORT-OK" in out
