"""Prefix caching + self-speculative decoding on the paged KV pool:
cached-vs-cold parity, copy-on-write isolation under eviction, LRU
index behavior, speculative accept/reject parity against greedy decode,
the k-token span kernel, and the prompt-lookup drafter."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.kernels import ops
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import PagedKVCache
from repro.serve.scheduler import Request

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


def cold_ref(cfg, params, prompt, max_new, window=64):
    """Solo greedy run with no prefix cache and no speculation."""
    eng = ServeEngine(cfg, CTX, window=window, max_batch=1, chunk=4,
                      page_size=8, prefix_cache=False)
    return eng.run(params, [Request(rid=0, prompt=prompt,
                                    max_new=max_new)])[0]


# -------------------------------------------------------- prefix caching


def test_prefix_cache_warm_rerun_matches_cold(qwen):
    """A re-submitted prompt must skip the cached full pages (suffix-only
    prefill) and still reproduce the cold greedy tokens exactly — the
    cached KV pages hold the same values a fresh prefill would compute."""
    cfg, params = qwen
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 21)
    ref = cold_ref(cfg, params, prompt, 10)
    eng = ServeEngine(cfg, CTX, window=64, max_batch=2, chunk=4,
                      page_size=8)
    o1 = eng.run(params, [Request(rid=0, prompt=prompt, max_new=10)])[0]
    o2 = eng.run(params, [Request(rid=0, prompt=prompt, max_new=10)])[0]
    np.testing.assert_array_equal(o1, ref)
    np.testing.assert_array_equal(o2, ref)
    # 21 tokens = 2 full pages of 8 cached + 5-token suffix prefilled
    assert eng.counters["cached_prompt_tokens"] == 16
    assert eng.counters["suffix_prefills"] == 1
    assert eng.prefix_hit_rate == pytest.approx(16 / 42)


def test_prefix_cache_same_boundary_sharing(qwen):
    """Identical prompts admitted in one scheduling boundary: the first
    registers its pages, the rest share them immediately."""
    cfg, params = qwen
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 21)
    ref = cold_ref(cfg, params, prompt, 10)
    eng = ServeEngine(cfg, CTX, window=64, max_batch=3, chunk=4,
                      page_size=8)
    out = eng.run(params, [Request(rid=i, prompt=prompt, max_new=10)
                           for i in range(3)])
    for i in range(3):
        np.testing.assert_array_equal(out[i], ref)
    assert eng.counters["suffix_prefills"] == 2  # rid 1 and 2
    assert eng.kv.counters["pages_shared"] == 4  # 2 pages x 2 sharers


def test_prefix_cache_partial_prefix_hit(qwen):
    """Prompts sharing only a prefix hit exactly the page-aligned shared
    region; the divergent tails stay private (CoW-safe by construction)."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 16)  # 2 full pages
    pa = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 5)])
    pb = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 7)])
    eng = ServeEngine(cfg, CTX, window=64, max_batch=2, chunk=4,
                      page_size=8)
    oa = eng.run(params, [Request(rid=0, prompt=pa, max_new=8)])[0]
    ob = eng.run(params, [Request(rid=0, prompt=pb, max_new=8)])[0]
    np.testing.assert_array_equal(oa, cold_ref(cfg, params, pa, 8))
    np.testing.assert_array_equal(ob, cold_ref(cfg, params, pb, 8))
    # the second admission hit exactly the 16 shared-prefix tokens
    assert eng.counters["cached_prompt_tokens"] == 16


def test_multiturn_followup_hits_generated_pages(qwen):
    """Completion publishes generated pages too: a follow-up turn whose
    prompt extends (prompt + response) reuses them."""
    cfg, params = qwen
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 11)
    eng = ServeEngine(cfg, CTX, window=64, max_batch=1, chunk=4,
                      page_size=8)
    first = eng.run(params, [Request(rid=0, prompt=prompt, max_new=13)])[0]
    follow = np.concatenate([prompt, first,
                             rng.integers(0, cfg.vocab_size, 6)])
    out = eng.run(params, [Request(rid=0, prompt=follow, max_new=8)])[0]
    np.testing.assert_array_equal(out, cold_ref(cfg, params, follow, 8))
    # 11 + 13 = 24 tokens of turn one -> 3 full pages cached
    assert eng.counters["cached_prompt_tokens"] == 24


def test_prefix_cache_with_eviction_and_preemption_parity(qwen):
    """Shared-prefix traffic through a pool too small to keep everything:
    LRU eviction of cached pages and (possibly) preemption must never
    corrupt a sharer — every output equals its solo cold run."""
    cfg, params = qwen
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab_size, 16)
    ps = [np.concatenate([shared, rng.integers(0, cfg.vocab_size, n)])
          for n in (3, 5, 2, 7, 4)]
    eng = ServeEngine(cfg, CTX, window=64, max_batch=3, chunk=4,
                      page_size=8, num_pages=14)
    out = eng.run(params, [Request(rid=i, prompt=p, max_new=14)
                           for i, p in enumerate(ps)])
    assert eng.kv.counters["pages_evicted"] >= 1
    for i, p in enumerate(ps):
        np.testing.assert_array_equal(out[i], cold_ref(cfg, params, p, 14))


def test_prefix_cache_int8_pages(qwen):
    """int8 page quantization composes with sharing: a warm rerun equals
    the int8 cold run bit-for-bit (same quantized pages are reused)."""
    cfg, params = qwen
    ctx8 = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        decode_cache_dtype=jnp.int8)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 19)
    cold = ServeEngine(cfg, ctx8, window=48, max_batch=1, chunk=4,
                       page_size=8, prefix_cache=False)
    ref = cold.run(params, [Request(rid=0, prompt=prompt, max_new=10)])[0]
    eng = ServeEngine(cfg, ctx8, window=48, max_batch=1, chunk=4,
                      page_size=8)
    o1 = eng.run(params, [Request(rid=0, prompt=prompt, max_new=10)])[0]
    o2 = eng.run(params, [Request(rid=0, prompt=prompt, max_new=10)])[0]
    np.testing.assert_array_equal(o1, ref)
    np.testing.assert_array_equal(o2, ref)
    assert eng.counters["cached_prompt_tokens"] == 16


# ------------------------------------------------ copy-on-write / index


def _unit_kv(cfg, num_pages=8, page_size=4, max_batch=2):
    return PagedKVCache(cfg, CTX, num_pages=num_pages, page_size=page_size,
                        max_batch=max_batch, max_pages_per_seq=4)


def _copy_fn(pages, src, dst):
    return {sl: {n: a.at[:, dst].set(a[:, src]) for n, a in sub.items()}
            for sl, sub in pages.items()}


def test_cow_fork_isolates_writers(qwen):
    """fork() must give the writer a private copy: mutating the forked
    page leaves the shared original (and its other sharer) untouched."""
    cfg, _ = qwen
    kv = _unit_kv(cfg)
    rng = np.random.default_rng(7)
    tokens = rng.integers(0, cfg.vocab_size, 4)  # one full page
    assert kv.grow(0, 5)
    src = int(kv._table[0, 0])
    # stamp recognizable content into the page
    kv.pages = jax.tree.map(lambda a: a.at[:, src].set(1.0), kv.pages)
    assert kv.register_prefix(0, np.append(tokens, 0)) == 1
    cached, pids = kv.lookup_prefix(np.append(tokens, 0))
    assert cached == 4 and pids == [src]
    kv.adopt_prefix(1, pids)
    assert kv._ref[src] == 2
    assert kv.ensure_private(1, 0, _copy_fn)  # forces the fork
    new = int(kv._table[1, 0])
    assert new != src and kv._ref[src] == 1 and kv._ref[new] == 1
    assert kv.counters["pages_forked"] == 1
    # write through the fork; the original must keep its content
    kv.pages = jax.tree.map(lambda a: a.at[:, new].set(-2.0), kv.pages)
    leaf = kv.pages[next(iter(kv.pages))]["k"]
    assert float(jnp.min(leaf[:, src])) == 1.0
    assert float(jnp.max(leaf[:, new])) == -2.0


def test_lru_eviction_spares_referenced_pages(qwen):
    """Allocation under pressure evicts only cached pages with refcount
    zero, least-recently-used first; referenced pages are never stolen."""
    cfg, _ = qwen
    kv = _unit_kv(cfg, num_pages=5, page_size=4)  # 4 usable pages
    rng = np.random.default_rng(8)
    ta = rng.integers(0, cfg.vocab_size, 4)
    tb = rng.integers(0, cfg.vocab_size, 4)
    assert kv.grow(0, 4)
    kv.register_prefix(0, np.append(ta, 0))
    pa = int(kv._table[0, 0])
    kv.release(0)  # ref 0, stays cached
    assert kv.grow(0, 4)
    kv.register_prefix(0, np.append(tb, 0))
    pb = int(kv._table[0, 0])
    assert kv.lookup_prefix(np.append(ta, 0))[0] == 4  # refresh A's LRU
    kv.release(0)
    # two cached pages (A newer tick), two free; demand all four: the
    # cached ones are evicted (B first: least recently used)
    assert kv.grow(1, 16)
    assert kv.counters["pages_evicted"] == 2
    assert kv.lookup_prefix(np.append(ta, 0))[0] == 0  # both gone
    assert kv.lookup_prefix(np.append(tb, 0))[0] == 0
    # everything referenced now: a fifth page does not exist
    assert not kv.grow(0, 4)
    assert kv._ref[pa] >= 0 and kv._ref[pb] >= 0


def test_abort_adoption_rolls_back_hit_counters(qwen):
    """An admission that adopts cached pages but then fails grow() must
    not leave its lookup/share counter bumps behind — retries would
    inflate the reported hit metrics arbitrarily."""
    cfg, _ = qwen
    kv = _unit_kv(cfg)
    rng = np.random.default_rng(18)
    tokens = rng.integers(0, cfg.vocab_size, 9)  # 2 full pages + 1
    assert kv.grow(0, 9)
    kv.register_prefix(0, tokens)
    kv.release(0)
    before = dict(kv.counters)
    cached, pids = kv.lookup_prefix(tokens)
    assert cached == 8
    kv.adopt_prefix(1, pids)
    kv.abort_adoption(1, cached, pids)
    assert kv.counters == before
    assert kv.slot_pages(1) == [] and int(kv._frontier[1]) == 0
    # the pages are still cached: a later retry hits again
    assert kv.lookup_prefix(tokens)[0] == 8


def test_lookup_verifies_block_tokens_on_hash_collision(qwen):
    """The chain hash is a 64-bit filter, not a proof: a colliding index
    entry with different block tokens must not serve its pages."""
    cfg, _ = qwen
    kv = _unit_kv(cfg)
    rng = np.random.default_rng(17)
    tokens = rng.integers(0, cfg.vocab_size, 5)
    h = kv.prefix_hashes(tokens)[0]
    # forge a colliding entry: same chain hash, different content
    assert kv.grow(0, 4)
    pid = int(kv._table[0, 0])
    kv._published[pid] = h
    kv._index[h] = (pid, ("not", "these", "tokens", "!"))
    cached, pids = kv.lookup_prefix(tokens)
    assert cached == 0 and pids == []


def test_chain_hash_certifies_whole_prefix(qwen):
    """The block hash chains through ancestors: an identical second page
    behind a *different* first page must not hit."""
    cfg, _ = qwen
    kv = _unit_kv(cfg, num_pages=8, page_size=4)
    rng = np.random.default_rng(9)
    common = rng.integers(0, cfg.vocab_size, 4)
    a = np.concatenate([rng.integers(0, cfg.vocab_size, 4), common, [1]])
    b = np.concatenate([rng.integers(0, cfg.vocab_size, 4), common, [1]])
    assert kv.grow(0, 8)
    kv.register_prefix(0, a)
    assert kv.lookup_prefix(a)[0] == 8
    assert kv.lookup_prefix(b)[0] == 0  # page 2 content equal, chain not


# --------------------------------------------------- speculative decode


def test_spec_greedy_parity_mixed_batch(qwen):
    """draft_k > 0 must reproduce the plain engine's greedy tokens for a
    mixed-length batch, while actually accepting drafts (random-init
    greedy falls into repetitive attractors the n-gram drafter nails)."""
    cfg, params = qwen
    rng = np.random.default_rng(1)
    ps = [rng.integers(0, cfg.vocab_size, n) for n in (9, 14, 21)]
    eng = ServeEngine(cfg, CTX, window=64, max_batch=3, chunk=4,
                      page_size=8, draft_k=4)
    out = eng.run(params, [Request(rid=i, prompt=p, max_new=20)
                           for i, p in enumerate(ps)])
    for i, p in enumerate(ps):
        np.testing.assert_array_equal(out[i], cold_ref(cfg, params, p, 20))
    assert eng.acceptance_length > 1.5  # drafts really were accepted
    assert (eng.counters["spec_tokens"]
            == sum(len(out[i]) for i in range(3)))


def test_spec_eos_parity(qwen):
    """EOS inside an accepted span: emission must stop at the EOS token
    exactly as the non-speculative engine does."""
    cfg, params = qwen
    rng = np.random.default_rng(10)
    p = rng.integers(0, cfg.vocab_size, 10)
    full = cold_ref(cfg, params, p, 12, window=48)
    eos = int(full[4])
    plain = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                        page_size=8, eos_id=eos, prefix_cache=False)
    want = plain.run(params, [Request(rid=0, prompt=p, max_new=12)])[0]
    spec = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                       page_size=8, eos_id=eos, draft_k=3,
                       prefix_cache=False)
    got = spec.run(params, [Request(rid=0, prompt=p, max_new=12)])[0]
    np.testing.assert_array_equal(got, want)
    assert got[-1] == eos and len(got) < 12


def test_spec_sampling_routes_to_plain_chunk(qwen):
    """With temperature > 0 greedy-match acceptance would skew the output
    distribution, so run() takes the plain 1-token chunk: no span work is
    paid and the sampled stream is identical to a draft_k=0 engine."""
    cfg, params = qwen
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size, 9)
    eng = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                      page_size=8, draft_k=3, prefix_cache=False,
                      temperature=0.8)
    out = eng.run(params, [Request(rid=0, prompt=p, max_new=10)],
                  key=jax.random.key(7))[0]
    plain = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                        page_size=8, prefix_cache=False, temperature=0.8)
    want = plain.run(params, [Request(rid=0, prompt=p, max_new=10)],
                     key=jax.random.key(7))[0]
    np.testing.assert_array_equal(out, want)
    assert len(out) == 10
    assert eng.counters["spec_steps"] == 0  # span path never ran
    assert eng.acceptance_length == 1.0


def test_spec_with_prefix_cache_and_pallas_kernel(qwen):
    """The span decode routes through the k-token Pallas kernel under
    attn_impl='pallas_interpret' and matches the gather-oracle engine."""
    cfg, params = qwen
    ctxp = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        attn_impl="pallas_interpret")
    rng = np.random.default_rng(12)
    p = rng.integers(0, cfg.vocab_size, 19)
    kern = ServeEngine(cfg, ctxp, window=48, max_batch=1, chunk=4,
                       page_size=8, draft_k=2)
    orac = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                       page_size=8, draft_k=2)
    for _ in range(2):  # second run exercises the cached-prefix span
        ok_ = kern.run(params, [Request(rid=0, prompt=p, max_new=8)])[0]
        oo = orac.run(params, [Request(rid=0, prompt=p, max_new=8)])[0]
        np.testing.assert_array_equal(ok_, oo)
    assert kern.counters["suffix_prefills"] == 1


def test_spec_requires_paged_backend(qwen):
    cfg, _ = qwen
    with pytest.raises(ValueError):
        ServeEngine(cfg, CTX, window=32, max_batch=1, chunk=4,
                    paged=False, draft_k=2)


def test_drafter_prefers_full_continuation(qwen):
    """Unit: the prompt-lookup drafter must pick the latest bigram match
    whose continuation is fully known, not the tip match whose
    continuation is unwritten history."""
    cfg, _ = qwen
    eng = ServeEngine(cfg, CTX, window=64, max_batch=2, chunk=4,
                      page_size=8, draft_k=3)
    hist = jnp.zeros((2, 64), jnp.int32)
    # row 0: strict repetition 5,7,5,7,... tip bigram (7,5) recurs
    hist = hist.at[0, :10].set(jnp.asarray([5, 7] * 5))
    # row 1: no earlier occurrence of the tip bigram
    hist = hist.at[1, :6].set(jnp.asarray([1, 2, 3, 4, 5, 6]))
    pos = jnp.asarray([10, 6], jnp.int32)
    tok = jnp.asarray([[5], [9]], jnp.int32)
    drafts = np.asarray(eng._draft_tokens(hist, pos, tok))
    np.testing.assert_array_equal(drafts[0], [7, 5, 7])  # full continuation
    np.testing.assert_array_equal(drafts[1], [-1, -1, -1])  # miss


# ------------------------------------------------------ span kernel


def test_paged_span_kernel_matches_ref():
    key = jax.random.key(0)
    b, t, h, kv, d, p, m, n = 3, 4, 8, 2, 32, 8, 4, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, d))
    kp = jax.random.normal(jax.random.fold_in(key, 2), (n, p, kv, d))
    vp = jax.random.normal(jax.random.fold_in(key, 3), (n, p, kv, d))
    table = jnp.array([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]],
                      jnp.int32)
    pos = jnp.array([17, 6, 27], jnp.int32)
    for window in (None, 7):
        out = ops.paged_decode_span_attention(
            q, kp, vp, table, pos, impl="interpret", window=window)
        want = ops.paged_decode_span_attention(
            q, kp, vp, table, pos, impl="ref", window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_span_kernel_t1_matches_single_token_kernel():
    """A span of one token must agree with the original scalar-prefetch
    decode kernel (pos conventions: span pos counts tokens BEFORE it)."""
    key = jax.random.key(4)
    b, h, kv, d, p, n = 2, 4, 2, 32, 8, 8
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(key, 2), (n, p, kv, d))
    vp = jax.random.normal(jax.random.fold_in(key, 3), (n, p, kv, d))
    table = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pos = jnp.array([11, 19], jnp.int32)
    single = ops.paged_decode_attention(q, kp, vp, table, pos + 1,
                                        impl="interpret")
    span = ops.paged_decode_span_attention(q[:, None], kp, vp, table, pos,
                                           impl="interpret")
    np.testing.assert_allclose(np.asarray(span[:, 0]), np.asarray(single),
                               rtol=1e-5, atol=1e-5)


def test_span_decode_matches_sequential_paged_decode(qwen):
    """Model-level: one span call over T tokens reproduces T sequential
    paged decode steps (logits and written pages)."""
    cfg, params = qwen
    b, p_, m, n = 2, 8, 4, 16
    spec = api.paged_state_spec(cfg, n, p_, b, m, CTX)
    pages = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         spec)["pages"]
    table = jnp.array([[1, 2, 3, 0], [4, 5, 6, 0]], jnp.int32)
    rng = np.random.default_rng(13)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 5)), jnp.int32)
    st = {"pages": pages, "page_table": table,
          "pos": jnp.zeros((b,), jnp.int32)}
    seq_logits = []
    for t in range(5):
        lg, st = api.decode_paged_fn(params, toks[:, t:t + 1], st, cfg, CTX)
        seq_logits.append(lg[:, 0])
    seq_logits = jnp.stack(seq_logits, 1)
    st2 = {"pages": pages, "page_table": table,
           "pos": jnp.zeros((b,), jnp.int32)}
    span_logits, st2 = api.decode_span_paged_fn(params, toks, st2, cfg, CTX)
    np.testing.assert_allclose(np.asarray(seq_logits),
                               np.asarray(span_logits),
                               rtol=1e-5, atol=1e-5)
    for a, bb in zip(jax.tree.leaves(st["pages"]),
                     jax.tree.leaves(st2["pages"])):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(bb, dtype=np.float32),
                                   rtol=1e-5, atol=1e-5)
