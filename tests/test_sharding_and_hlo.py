"""Sharding rule resolution (hypothesis properties) + HLO analyzer units +
multi-device subprocess integration (mini dry-run, compressed grads)."""

from optional_deps import hypothesis, st  # real or deterministic shim
import numpy as np
import pytest

from repro.core.hlo_analysis import (axes_for_groups, parse_replica_groups,
                                     shape_bytes)


# ----------------------------------------------------------- hlo parsing


def test_shape_bytes():
    assert shape_bytes("f32[4,4]") == 64
    assert shape_bytes("bf16[2,3]{1,0}") == 12
    assert shape_bytes("(s32[], f32[10], bf16[4])") == 4 + 40 + 8
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("f8e4m3fn[100]") == 100


def test_parse_replica_groups_list_format():
    groups = parse_replica_groups("replica_groups={{0,1},{2,3}}, x=y")
    assert groups == ((0, 1), (2, 3))


def test_parse_replica_groups_iota_format():
    groups = parse_replica_groups(
        "replica_groups=[2,4]<=[4,2]T(1,0), use_global_device_ids=true")
    assert groups == ((0, 2, 4, 6), (1, 3, 5, 7))
    groups = parse_replica_groups("replica_groups=[4,2]<=[8]")
    assert groups == ((0, 1), (2, 3), (4, 5), (6, 7))


def test_axes_for_groups():
    # mesh (4, 2) ("data", "model"), row-major ids
    model_groups = ((0, 1), (2, 3), (4, 5), (6, 7))
    assert axes_for_groups(model_groups, (4, 2), ("data", "model")) == \
        ("model",)
    data_groups = ((0, 2, 4, 6), (1, 3, 5, 7))
    assert axes_for_groups(data_groups, (4, 2), ("data", "model")) == \
        ("data",)
    all_groups = ((0, 1, 2, 3, 4, 5, 6, 7),)
    assert set(axes_for_groups(all_groups, (4, 2), ("data", "model"))) == \
        {"data", "model"}


def test_trip_count_scaling(subproc):
    """Analyzer scales while-body costs by known_trip_count (the core fix
    over XLA cost_analysis)."""
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.hlo_analysis import analyze_compiled_text
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
S = lambda *s: NamedSharding(mesh, P(*s))
def make(L):
    def step(ws, x):
        def body(x, w):
            return jax.lax.with_sharding_constraint(x @ w, S("data", None)), None
        out, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(out.astype(jnp.float32)**2)
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.bfloat16)
    xs = jax.ShapeDtypeStruct((32, 64), jnp.bfloat16)
    f = jax.jit(jax.grad(step), in_shardings=(S(None,None,"model"), S("data",None)),
                out_shardings=S(None,None,"model"))
    txt = f.lower(ws, xs).compile().as_text()
    return analyze_compiled_text(txt, (4,2), ("data","model"))
r5, r10 = make(5), make(10)
assert 1.9 < r10.flops / r5.flops < 2.1, (r5.flops, r10.flops)
c5 = sum(c.multiplier for c in r5.collectives)
c10 = sum(c.multiplier for c in r10.collectives)
assert 1.9 < c10 / c5 < 2.1, (c5, c10)
print("TRIPS-OK", r5.flops, r10.flops)
""", devices=8)
    assert "TRIPS-OK" in out


# ------------------------------------------------------ sharding rules


from repro.sharding.axes import BASELINE_RULES, FSDP_RULES, resolve_spec


class FakeMesh:
    def __init__(self, shape, names):
        import numpy as _np
        self.devices = _np.zeros(shape)
        self.axis_names = names


@hypothesis.given(
    dim=st.integers(min_value=1, max_value=4096),
    logical=st.sampled_from(["batch", "heads", "mlp", "vocab", "expert"]),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_resolve_spec_divisibility_property(dim, logical):
    """Resolved specs always evenly divide the dimension (never padded)."""
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    dropped = []
    spec = resolve_spec((logical,), (dim,), mesh, FSDP_RULES, dropped)
    entry = spec[0] if len(spec) > 0 else None
    if entry is not None:
        axes = entry if isinstance(entry, tuple) else (entry,)
        sizes = {"pod": 2, "data": 16, "model": 16}
        prod = 1
        for a in axes:
            prod *= sizes[a]
        assert dim % prod == 0


def test_resolve_spec_no_axis_reuse():
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    # batch takes (pod,data); a second batch-like dim must not reuse them
    spec = resolve_spec(("batch", "expert"), (32, 384), mesh, FSDP_RULES)
    used = []
    for entry in spec:
        if entry is None:
            continue
        used.extend(entry if isinstance(entry, tuple) else (entry,))
    assert len(used) == len(set(used))


def test_resolve_spec_fallback_replicates():
    mesh = FakeMesh((16, 16), ("data", "model"))
    dropped = []
    spec = resolve_spec(("kv_heads",), (2,), mesh, BASELINE_RULES, dropped)
    assert spec == ()  # replicated (trailing None trimmed)
    assert dropped == [("kv_heads", 2)]


# -------------------------------------------- multi-device integration


def test_mini_dryrun_multipod(subproc):
    """Scaled-down production mesh (2,2,2): lower+compile a smoke arch
    train step and a decode step; analyze collectives."""
    out = subproc("""
import jax
from repro.launch.mesh import make_mesh
from repro.launch import cells as C
import repro.configs.registry as R
import dataclasses

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
# shrink the cells: swap full config for smoke + small shape
orig = R.get_cell
def small_cell(arch, shape):
    cell = orig(arch, shape)
    smoke = R._module(arch).SMOKE
    sp = dataclasses.replace(cell.shape, global_batch=8, seq_len=32)
    st = dataclasses.replace(cell.settings, microbatches=2)
    return dataclasses.replace(cell, config=smoke, shape=sp, settings=st)
C.get_cell = small_cell
for arch, shape in [("qwen2_0_5b", "train_4k"), ("mixtral_8x22b", "train_4k"),
                    ("rwkv6_1_6b", "decode_32k"), ("whisper_small", "prefill_32k")]:
    built = C.build_cell(arch, shape, mesh)
    compiled = C.lower_cell(built).compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    from repro.core.hlo_analysis import analyze_compiled_text
    rep = analyze_compiled_text(compiled.as_text(), (2,2,2),
                                ("pod","data","model"))
    assert rep.flops > 0, arch
    print("MINI-OK", arch, shape, int(rep.flops), len(rep.collectives))
print("ALL-MINI-OK")
""", devices=8, timeout=420)
    assert "ALL-MINI-OK" in out


def test_compressed_pod_grads(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.compress import make_compressed_grad_fn, init_error_state
from repro.launch.mesh import make_mesh
mesh = make_mesh((2,2,2), ("pod","data","model"))
def loss_fn(params, batch):
    y = batch["x"] @ params["w"]
    l = jnp.mean((y - batch["t"])**2)
    return l, {"loss": l}
params = {"w": jnp.ones((8,8))*0.3}
batch = {"x": jnp.arange(64.).reshape(8,8)/10, "t": jnp.ones((8,8))}
fn = jax.jit(make_compressed_grad_fn(loss_fn, mesh, {"x": P("pod"), "t": P("pod")}))
err = init_error_state(params)
(l, m), g, err2 = fn(params, batch, err)
(_, _), g_ref = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
rel = float(jnp.max(jnp.abs(g["w"] - g_ref["w"])) / jnp.max(jnp.abs(g_ref["w"])))
assert rel < 0.02, rel
# error feedback: second call with the error state further reduces bias
(l2, _), g2, err3 = fn(params, batch, err2)
print("COMPRESS-OK", rel)
""", devices=8)
    assert "COMPRESS-OK" in out
