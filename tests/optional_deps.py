"""Optional-dependency shim for ``hypothesis``.

Tier-1 must collect and run everywhere, including containers where
``hypothesis`` cannot be installed. When the real package is present we
re-export it untouched; otherwise we provide a deterministic mini
property-based fallback with the same decorator surface used by this
test suite (``given``/``settings`` and the ``integers``/``floats``/
``lists``/``sampled_from`` strategies). The fallback draws a fixed
number of seeded examples per test — weaker than real hypothesis (no
shrinking, no database) but it keeps every property exercised.
"""

from __future__ import annotations

import functools
import types
import zlib

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in hypothesis-less envs
    HAVE_HYPOTHESIS = False

    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng, boundary: bool):
            return self._draw(rng, boundary)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng, b: min_value if b else int(
            rng.integers(min_value, max_value + 1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng, b: float(min_value) if b else float(
            rng.uniform(min_value, max_value)))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng, b: elements[0] if b else elements[
            int(rng.integers(len(elements)))])

    def _lists(elems, min_size=0, max_size=10):
        def draw(rng, b):
            n = min_size if b else int(rng.integers(min_size, max_size + 1))
            return [elems.draw(rng, False) for _ in range(n)]
        return _Strategy(draw)

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(*arg_strategies, **kw_strategies):
        def deco(fn):
            n = getattr(fn, "_shim_max_examples", 20)

            @functools.wraps(fn)
            def wrapper():
                for i in range(n):
                    seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}:{i}"
                                      .encode())
                    rng = _np.random.default_rng(seed)
                    boundary = i == 0  # probe min/first values once
                    args = [s.draw(rng, boundary) for s in arg_strategies]
                    kwargs = {k: s.draw(rng, boundary)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # drop functools' __wrapped__ so pytest sees a zero-arg
            # signature and does not treat drawn params as fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco

    hypothesis = types.SimpleNamespace(given=_given, settings=_settings)
    st = types.SimpleNamespace(integers=_integers, floats=_floats,
                               lists=_lists, sampled_from=_sampled_from)

__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]
