"""Serve traffic through the fleet simulator: scenario suites as
tests, seeded-arrival determinism properties, grammar stability across
autoscale policies, trace calibration, and the serve power pipeline.

Every ``benchmarks/scenarios/*.json`` runs here as one pytest case (the
same file bench_fleet emits as a row), so a scenario regression fails
tier-1 twice — once as a benchmark MISMATCH, once as a test."""

import json
from pathlib import Path

import pytest
from optional_deps import hypothesis, st  # real or deterministic shim

from repro.core import hwspec
from repro.fleet import (ArrivalProcess, FleetConfig, FleetSimulator,
                         JobSpec, PowerModel, ServeJobSpec, ServeSLO,
                         ServiceTimeModel, grammar_ok, load_scenario,
                         load_scenario_paths, run_scenario,
                         serve_calibration_check,
                         service_model_from_trace, validate_scenario)
from repro.obs.steptrace import StepTrace

SCENARIO_DIR = Path(__file__).resolve().parent.parent / "benchmarks" \
    / "scenarios"
SCENARIO_PATHS = load_scenario_paths(SCENARIO_DIR)

_SERVICE = dict(prefill_s_per_token=0.001, chunk_base_s=0.08,
                chunk_per_slot_s=0.02, chunk_steps=8)


def _mixed_sim(*, seed=7, rate=2.0, policy="auto", horizon=600.0,
               mtbf_hours=None):
    """A small mixed serve+train pod, the shared fixture for the
    determinism / grammar properties (sub-second per run)."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=4,
                      host_mtbf_hours=mtbf_hours, repair_hours=1.0,
                      seed=seed)
    train = JobSpec(name="t0", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=300)
    svc = ServeJobSpec(
        name="chat", chips=64,
        arrivals=ArrivalProcess(rate_rps=rate, prompt_tokens=64,
                                output_tokens=32),
        slo=ServeSLO(ttft_s=2.0, tpot_s=0.05),
        service=ServiceTimeModel(**_SERVICE),
        replicas=1, min_replicas=1, max_replicas=2, max_batch=4,
        scale_policy=policy, spinup_s=10.0, control_interval_s=30.0)
    sim = FleetSimulator(cfg, [train], serve_jobs=[svc])
    sim.run(horizon)
    return sim


def _serve_dump(sim):
    """The full determinism surface of one serve job: the request log,
    the goodput ledger, and both summaries, as one canonical string."""
    rt = sim.serve["chat"]
    return json.dumps({
        "log": rt.request_log,
        "ledger": [(e.kind, round(e.seconds, 9), e.steps, e.note)
                   for e in rt.ledger.events],
        "slo": rt.slo_summary(),
        "fleet": sim.fleet_summary(),
    }, sort_keys=True)


# ----------------------------------------------------- scenario suites


@pytest.mark.parametrize(
    "path", SCENARIO_PATHS, ids=[p.stem for p in SCENARIO_PATHS])
def test_scenario_validates_and_passes(path):
    doc = json.loads(path.read_text())
    assert validate_scenario(doc) == []
    res = run_scenario(doc)
    failed = [c for c in res["checks"] if not c["ok"]]
    assert res["ok"], f"failed expect checks: {failed}"
    assert res["checks"], "scenario must assert something"


def test_scenario_suite_has_required_gates():
    """The suite must contain at least one autoscaling-beats-static
    scenario (baseline ref on slo_goodput) and at least one
    SLO-violation-under-burst scenario."""
    docs = [json.loads(p.read_text()) for p in SCENARIO_PATHS]
    assert any(
        any("ref" in c and "slo_goodput" in c["metric"]
            for c in d.get("expect", []))
        for d in docs if d.get("baseline"))
    assert any(
        d.get("serve_jobs") and any(
            j.get("arrivals", {}).get("burst_x", 1.0) > 1.0
            for j in d["serve_jobs"])
        and any(c["metric"].endswith("ttft_viol")
                for c in d.get("expect", []))
        for d in docs)


def test_run_scenario_with_measured_service_model():
    """run_scenario(service=...) substitutes a measured model into both
    arms — the path the calibration gate uses."""
    doc = json.loads(
        (SCENARIO_DIR / "serve_burst_slo_violation.json").read_text())
    model = ServiceTimeModel(**_SERVICE)
    res = run_scenario(doc, service=model)
    base = run_scenario(doc)
    # identical coefficients => identical metrics, model path exercised
    assert res["metrics"] == base["metrics"]


# ------------------------------------------- validator negative space


def _valid_doc():
    return json.loads(
        (SCENARIO_DIR / "serve_autoscale_vs_static.json").read_text())


def test_validate_rejects_unknown_keys_everywhere():
    for mutate in (
            lambda d: d.update(extra_knob=1),
            lambda d: d["fleet"].update(cooling="liquid"),
            lambda d: d["serve_jobs"][0].update(turbo=True),
            lambda d: d["serve_jobs"][0]["arrivals"].update(ramp=2),
            lambda d: d["serve_jobs"][0]["slo"].update(p99_s=1.0),
            lambda d: d["serve_jobs"][0]["service"].update(source="x"),
            lambda d: d["expect"][0].update(tolerance=0.1),
    ):
        doc = _valid_doc()
        mutate(doc)
        problems = validate_scenario(doc)
        assert any("unknown keys" in p for p in problems), mutate


def test_validate_rejects_non_reproducible_seeds():
    for bad in ("time", None, 1.5, True):
        doc = _valid_doc()
        doc["fleet"]["seed"] = bad
        problems = validate_scenario(doc)
        assert any("non-reproducible seeds are rejected" in p
                   for p in problems), bad


def test_validate_rejects_malformed_expects_and_schema():
    doc = _valid_doc()
    doc["expect"][0]["op"] = "~="
    assert any("op must be one of" in p for p in validate_scenario(doc))
    doc = _valid_doc()
    c = doc["expect"][0]
    c["value"] = 1.0  # now has both value and ref
    assert "ref" in c or "value" in c
    doc["expect"][0] = {"metric": "serve/chat/slo_goodput", "op": ">",
                        "value": 0.5, "ref": "baseline:x"}
    assert any("exactly one of value/ref" in p
               for p in validate_scenario(doc))
    doc = _valid_doc()
    del doc["baseline"]
    assert any("ref used without a baseline" in p
               for p in validate_scenario(doc))
    doc = _valid_doc()
    doc["schema"] = "repro.fleet.scenario/v0"
    assert any("schema must be" in p for p in validate_scenario(doc))
    doc = _valid_doc()
    doc["description"] = ""
    assert any("description" in p for p in validate_scenario(doc))


def test_load_scenario_raises_on_invalid(tmp_path):
    p = tmp_path / "bad.json"
    doc = _valid_doc()
    doc["fleet"]["seed"] = "time"
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="non-reproducible"):
        load_scenario(p)


# ------------------------------------------------ determinism properties


@hypothesis.given(
    rate=st.floats(min_value=0.5, max_value=4.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    policy=st.sampled_from(["fixed", "auto"]))
@hypothesis.settings(max_examples=8, deadline=None)
def test_serve_same_seed_byte_identical(rate, seed, policy):
    """Same config + same seed => byte-identical request log, ledger
    event stream, and summaries — the open-loop arrival contract."""
    a = _serve_dump(_mixed_sim(seed=seed, rate=rate, policy=policy))
    b = _serve_dump(_mixed_sim(seed=seed, rate=rate, policy=policy))
    assert a == b


@hypothesis.given(
    rate=st.floats(min_value=0.5, max_value=4.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1))
@hypothesis.settings(max_examples=8, deadline=None)
def test_serve_arrivals_invariant_across_policies(rate, seed):
    """The seeded request trace is a property of (seed, job name,
    arrival process) alone: switching the autoscale policy must not
    move a single arrival, so fixed-vs-auto comparisons (the baseline
    arms in the scenario suites) run on the identical workload."""
    fixed = _mixed_sim(seed=seed, rate=rate, policy="fixed")
    auto = _mixed_sim(seed=seed, rate=rate, policy="auto")
    rf, ra = fixed.serve["chat"], auto.serve["chat"]
    assert rf.arrived == ra.arrived
    arr_f = {(rid, turn): t for (rid, turn, t, *_) in rf.request_log}
    arr_a = {(rid, turn): t for (rid, turn, t, *_) in ra.request_log}
    shared = set(arr_f) & set(arr_a)
    assert shared  # both arms finished plenty of requests
    assert all(arr_f[k] == arr_a[k] for k in shared)


@hypothesis.given(
    rate=st.floats(min_value=0.5, max_value=3.0),
    seed=st.integers(min_value=0, max_value=10**6),
    policy=st.sampled_from(["fixed", "auto"]))
@hypothesis.settings(max_examples=6, deadline=None)
def test_serve_grammar_stable_on_mixed_runs(rate, seed, policy):
    """Mixed serve+train runs with real failures stay inside the pinned
    five-kind ledger grammar for every job, at any rate / policy."""
    sim = _mixed_sim(seed=seed, rate=rate, policy=policy,
                     mtbf_hours=2.0, horizon=1800.0)
    assert all(grammar_ok(j.ledger) for j in sim.jobs.values())
    assert all(grammar_ok(rt.ledger) for rt in sim.serve.values())
    rt = sim.serve["chat"]
    # the ledger accounts every settled second exactly once
    summ = rt.slo_summary()
    assert summ["finished"] <= summ["arrived"]
    assert 0.0 <= summ["slo_goodput"] <= 1.0


# ------------------------------------------------- calibration + power


def _synthetic_trace(slope=0.002, base=0.02, steps=8):
    tr = StepTrace(source="serve")
    for _ in range(6):
        tr.record("prefill", 0.0128, tokens=128, cached=0, batch=1)
        for b in (1, 2, 3, 4):
            tr.record("decode", base + slope * (b - 1),
                      batch=b, steps=steps, tokens=b * steps)
    return tr


def test_service_model_from_trace_recovers_affine_law():
    m = service_model_from_trace(_synthetic_trace())
    assert m.chunk_base_s == pytest.approx(0.02, rel=1e-6)
    assert m.chunk_per_slot_s == pytest.approx(0.002, rel=1e-6)
    assert m.chunk_steps == 8
    assert m.prefill_s_per_token == pytest.approx(1e-4, rel=1e-6)
    assert m.source == "serve"
    # constant-batch trace: falls back to the exact mean
    tr = StepTrace(source="serve")
    for _ in range(5):
        tr.record("decode", 0.03, batch=2, steps=4, tokens=8)
    m2 = service_model_from_trace(tr)
    assert m2.chunk_base_s == pytest.approx(0.03)
    assert m2.chunk_per_slot_s == 0.0


def test_serve_calibration_check_passes_and_guards_sample_size():
    cal = serve_calibration_check(_synthetic_trace())
    assert cal["ok"] == 1.0
    assert cal["steady_admissions"] >= 8
    assert cal["rel_err"] <= 0.25
    # the mixed-batch trace replays at ~4% off the single-batch sim
    # operating point; a tightened tolerance must fail the gate
    tight = serve_calibration_check(_synthetic_trace(), tol=0.01)
    assert tight["ok"] == 0.0 and tight["rel_err"] > 0.01
    # a faster engine shows up directly in the measured side
    fast = serve_calibration_check(
        _synthetic_trace(base=0.01, slope=0.001))
    assert fast["measured_chunk_s"] < cal["measured_chunk_s"]
    assert fast["ok"] == 1.0


def test_power_serve_summary_joules_per_token():
    sim = _mixed_sim(seed=3, rate=2.0, policy="fixed", horizon=600.0)
    rt = sim.serve["chat"]
    pm = PowerModel(hwspec.get("tpu_v4"))
    ss = pm.serve_summary(rt.ledger, rt.spec.chips,
                          good_tokens=rt.good_tokens,
                          total_tokens=rt.total_tokens)
    assert ss["energy_j"] > 0
    assert ss["joules_per_token"] > 0
    assert ss["joules_per_good_token"] >= ss["joules_per_token"]
    assert ss["energy_kwh"] == pytest.approx(ss["energy_j"] / 3.6e6)
    empty = pm.serve_summary(rt.ledger, rt.spec.chips,
                             good_tokens=0, total_tokens=0)
    assert empty["joules_per_token"] == float("inf")


# ------------------------------------------------------- arrival model


def test_arrival_process_diurnal_and_burst_envelope():
    ap = ArrivalProcess(rate_rps=2.0, diurnal_amplitude=0.5,
                        diurnal_period_s=1000.0, burst_x=3.0,
                        burst_every_s=500.0, burst_len_s=50.0)
    rates = [ap.rate_at(t) for t in range(0, 1000, 7)]
    assert all(0.0 < r <= ap.peak_rate + 1e-9 for r in rates)
    assert max(rates) > 2.0  # burst/diurnal peak above the base rate
    in_burst, outside = ap.rate_at(510.0), ap.rate_at(400.0)
    assert in_burst > outside


def test_arrival_process_validation():
    with pytest.raises(ValueError):
        ArrivalProcess(rate_rps=0.0)
    with pytest.raises(ValueError):
        ArrivalProcess(rate_rps=1.0, diurnal_amplitude=1.5)
    with pytest.raises(ValueError):
        ArrivalProcess(rate_rps=1.0, burst_x=0.5)
    with pytest.raises(ValueError):
        ServeJobSpec(name="x", chips=64,
                     arrivals=ArrivalProcess(rate_rps=1.0),
                     slo=ServeSLO(), service=ServiceTimeModel(),
                     scale_policy="bananas")
