"""Quantization-native paged attention + chunked span prefill (PR 4):
int8 pages streamed through the scalar-prefetch kernels (in-VMEM
dequant) vs the gather-dequant oracle, chunked cold prefill vs the
dense full prefill on attention and hybrid (jamba) archs, and the
compile-count contract (varying prompt lengths -> one program)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.kernels import ops
from repro.models import api
from repro.models.blocks import ModelContext, paged_quantize
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


def _int8_pages(key, n, p, kv, d):
    """Random fp pages quantized per-(token, head) into (pages, scales)."""
    x = jax.random.normal(key, (n, p, kv, d)) * 2.0
    q, s = paged_quantize(x, jnp.int8)
    return q, s, (q.astype(jnp.float32) * s[..., None])


# ------------------------------------------------ int8 kernel parity


def test_int8_paged_decode_kernel_matches_dequant_oracle():
    """GQA + sliding window sweep: the int8 page stream (in-VMEM dequant)
    must match the gather-dequant oracle bit-for-bit in fp32 tolerance —
    the kernel reads half the bytes but the math is identical."""
    key = jax.random.key(0)
    b, h, kv, d, p, m, n = 3, 8, 2, 32, 8, 4, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, h, d))
    kp, ks, kf = _int8_pages(jax.random.fold_in(key, 2), n, p, kv, d)
    vp, vs, vf = _int8_pages(jax.random.fold_in(key, 3), n, p, kv, d)
    table = jnp.array([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]],
                      jnp.int32)
    pos = jnp.array([19, 9, 31], jnp.int32)
    for window in (None, 7):
        out = ops.paged_decode_attention(
            q, kp, vp, table, pos, k_scale=ks, v_scale=vs,
            impl="interpret", window=window)
        want = ops.paged_decode_attention(
            q, kp, vp, table, pos, k_scale=ks, v_scale=vs,
            impl="ref", window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # the ref path itself must equal the fp kernel on dequantized pages
        fp = ops.paged_decode_attention(q, kf, vf, table, pos,
                                        impl="interpret", window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                                   rtol=1e-4, atol=1e-4)


def test_int8_paged_span_kernel_matches_dequant_oracle():
    """Same contract for the k-token span kernel (speculative verify /
    chunked prefill): int8 scale pages ride the same table entry."""
    key = jax.random.key(7)
    b, t, h, kv, d, p, m, n = 2, 4, 8, 2, 32, 8, 4, 12
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, t, h, d))
    kp, ks, _ = _int8_pages(jax.random.fold_in(key, 2), n, p, kv, d)
    vp, vs, _ = _int8_pages(jax.random.fold_in(key, 3), n, p, kv, d)
    table = jnp.array([[1, 2, 3, 0], [4, 5, 6, 7]], jnp.int32)
    pos = jnp.array([13, 22], jnp.int32)
    for window in (None, 7):
        out = ops.paged_decode_span_attention(
            q, kp, vp, table, pos, k_scale=ks, v_scale=vs,
            impl="interpret", window=window)
        want = ops.paged_decode_span_attention(
            q, kp, vp, table, pos, k_scale=ks, v_scale=vs,
            impl="ref", window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_int8_span_model_level_matches_oracle_engine_path(qwen):
    """Model-level: one int8 span decode through the Pallas kernel equals
    the same call through the jnp gather-dequant oracle."""
    cfg, params = qwen
    ctx8k = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                         decode_cache_dtype=jnp.int8,
                         attn_impl="pallas_interpret")
    ctx8 = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        decode_cache_dtype=jnp.int8)
    b, p_, m, n = 2, 8, 4, 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 5)), jnp.int32)
    table = jnp.array([[1, 2, 3, 0], [4, 5, 6, 0]], jnp.int32)
    outs = []
    for ctx in (ctx8k, ctx8):
        spec = api.paged_state_spec(cfg, n, p_, b, m, ctx)
        pages = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             spec)["pages"]
        st = {"pages": pages, "page_table": table,
              "pos": jnp.zeros((b,), jnp.int32)}
        logits, st = api.decode_span_paged_fn(params, toks, st, cfg, ctx)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)


# --------------------------------------- chunked prefill (paged, attn)


def test_chunked_prefill_logit_parity_attention(qwen):
    """Chunked span prefill must reproduce the dense full prefill's
    last-token logits (pure-attention arch, multiple chunks): prompt
    pages hold identical KV whichever path wrote them."""
    cfg, params = qwen
    rng = np.random.default_rng(4)
    s, span, p_ = 21, 8, 4
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32)
    # dense full prefill oracle
    want, _ = api.prefill_fn(params, {"tokens": prompt}, cfg, CTX,
                             window=32)
    # chunked span prefill over zero pages with an identity-ish table
    n, m = 10, 8
    spec = api.paged_state_spec(cfg, n, p_, 1, m, CTX)
    pages = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                         spec)["pages"]
    table = jnp.arange(1, m + 1, dtype=jnp.int32)[None, :]
    logits = None
    for i in range(0, s, span):
        chunk = np.zeros((1, span), np.int32)
        t = min(span, s - i)
        chunk[0, :t] = np.asarray(prompt[0, i:i + t])
        st = {"pages": pages, "page_table": table,
              "pos": jnp.full((1,), i, jnp.int32)}
        logits, st = api.decode_span_paged_fn(
            params, jnp.asarray(chunk), st, cfg, CTX,
            valid=jnp.full((1,), t, jnp.int32))
        pages = st["pages"]
        last = logits[:, t - 1:t]
    np.testing.assert_allclose(np.asarray(last), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_engine_cold_prompts_share_constant_prefill_programs(qwen):
    """Compile-count contract: cold prompts of varying lengths (and
    cached-suffix re-runs) ride a constant program family — the full
    span program plus pow2 buckets for the final partial chunk, at most
    log2(span_len) programs no matter how many lengths are served."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=64, max_batch=2, chunk=4,
                      page_size=8, prefill_chunk=16)
    rng = np.random.default_rng(5)
    ps = [rng.integers(0, cfg.vocab_size, int(n))
          for n in (5, 9, 17, 23, 31)]
    out = eng.run(params, [Request(rid=i, prompt=p, max_new=6)
                           for i, p in enumerate(ps)])
    compiled = eng.counters["span_prefill_compiles"]
    assert compiled <= 3  # buckets {4, 8, 16} for span_len 16
    # new lengths + a suffix re-run: every bucket is already compiled
    more = [rng.integers(0, cfg.vocab_size, int(n))
            for n in (6, 11, 19, 27)]
    eng.run(params, [Request(rid=i, prompt=p, max_new=6)
                     for i, p in enumerate(more)])
    eng.run(params, [Request(rid=0, prompt=ps[-1], max_new=6)])
    assert eng.counters["span_prefill_compiles"] == compiled
    assert eng.counters["prefill_span_calls"] >= len(ps) + len(more) + 1
    solo = ServeEngine(cfg, CTX, window=64, max_batch=1, chunk=4,
                       page_size=8, prefix_cache=False)
    for i, p in enumerate(ps):
        want = solo.run(params, [Request(rid=0, prompt=p, max_new=6)])[0]
        np.testing.assert_array_equal(out[i], want)


def test_engine_chunked_prefill_swa_arch():
    """Chunked prefill composes with sliding-window masking (mixtral):
    span queries honor the window and the result matches the per-token
    oracle."""
    cfg = get_smoke("mixtral_8x22b")
    assert cfg.sliding_window is not None
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    eng = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                      page_size=4, prefill_chunk=8)
    rng = np.random.default_rng(6)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 14)), jnp.int32)}
    out = eng.generate(params, batch, max_new=10)
    ref = eng.generate_pertoken(params, batch, max_new=10)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------- chunked prefill (dense, jamba)


def test_jamba_chunked_prefill_logit_parity():
    """Hybrid stack (attention + mamba + moe): the dense span path's
    chunked prefill must reproduce the full prefill's last-token logits —
    recurrent state threads through chunks, attention stays absolute."""
    cfg = get_smoke("jamba_v01_52b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    rng = np.random.default_rng(7)
    s, span, window = 13, 4, 24
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32)
    want, _ = api.prefill_fn(params, {"tokens": prompt}, cfg, CTX,
                             window=window)
    n_c = -(-s // span)
    pad = n_c * span - s
    padded = np.zeros((1, n_c * span), np.int32)
    padded[0, pad:] = np.asarray(prompt[0])
    cache = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                         api.cache_spec(cfg, 1, window, CTX))
    logits = None
    for i in range(n_c):
        cache["pos"] = jnp.full((1,), i * span - pad, jnp.int32)
        logits, cache = api.decode_span_fn(
            params, jnp.asarray(padded[:, i * span:(i + 1) * span]),
            cache, cfg, CTX)
    np.testing.assert_allclose(np.asarray(logits[:, -1:]),
                               np.asarray(want), rtol=1e-4, atol=1e-4)


def test_jamba_engine_constant_prefill_programs_and_parity():
    """Engine-level jamba: varying prompt lengths share a constant dense
    span program family (full span + pow2 first-chunk buckets) and match
    the per-token oracle exactly."""
    cfg = get_smoke("jamba_v01_52b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    eng = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4,
                      prefill_chunk=8)
    assert not eng.paged and eng.chunk_prefill
    rng = np.random.default_rng(8)
    ps = [rng.integers(0, cfg.vocab_size, n) for n in (7, 11, 13, 18)]
    out = eng.run(params, [Request(rid=i, prompt=p, max_new=6)
                           for i, p in enumerate(ps)])
    compiled = eng.counters["span_prefill_dense_compiles"]
    assert compiled <= 2  # buckets {4, 8} for span_len 8
    eng.run(params, [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, n), max_new=6)
        for i, n in enumerate((6, 10, 15))])
    assert eng.counters["span_prefill_dense_compiles"] == compiled
    for i, p in enumerate(ps):
        ref = eng.generate_pertoken(
            params, {"tokens": jnp.asarray(p[None, :])}, max_new=6)
        np.testing.assert_array_equal(out[i], np.asarray(ref)[0])


# ------------------------------------------------------- accounting


def test_int8_per_token_bytes_capacity_ratio(qwen):
    """int8 pools must fit >= 1.5x the resident tokens of bf16 pools in
    the same HBM (the Ironwood int8-KV lever, scales included)."""
    cfg, _ = qwen
    from repro.serve.kv_cache import PagedKVCache
    ctx16 = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                         decode_cache_dtype=jnp.bfloat16)
    ctx8 = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        decode_cache_dtype=jnp.int8)
    kv16 = PagedKVCache(cfg, ctx16, num_pages=4, page_size=4, max_batch=1,
                        max_pages_per_seq=2)
    kv8 = PagedKVCache(cfg, ctx8, num_pages=4, page_size=4, max_batch=1,
                       max_pages_per_seq=2)
    ratio = kv16.per_token_bytes() / kv8.per_token_bytes()
    assert ratio >= 1.5, ratio


def test_dedup_stats_track_shared_pages(qwen):
    """Cross-request dedup: identical prompts admitted twice report the
    shared pages and the HBM bytes they saved."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                      page_size=8)
    rng = np.random.default_rng(9)
    p = rng.integers(0, cfg.vocab_size, 17)
    eng.run(params, [Request(rid=0, prompt=p, max_new=6)])
    eng.run(params, [Request(rid=0, prompt=p, max_new=6)])
    stats = eng.kv.dedup_stats()
    assert stats["pages_shared"] == 2  # two full pages adopted on rerun
    assert stats["pages_unique"] >= stats["pages_shared"]
    assert stats["bytes_saved"] == \
        2 * eng.page_size * eng.kv.per_token_bytes()
