"""Fleet simulator: event-engine determinism, goodput bounds, scheduler
invariants through reconfigurations, SDC rollback semantics, checkpoint-
interval policy, power/carbon ratios, Chrome-trace export, and the
sim-vs-ResilientTrainer bridge."""

import json

import pytest
from optional_deps import hypothesis, st  # real or deterministic shim

from repro.core import hwspec
from repro.core.goodput import GoodputLedger, modeled_goodput
from repro.core.sdc import SDCRateModel
from repro.fleet import (EventEngine, FleetConfig, FleetSimulator, JobSpec,
                         PowerModel, generation_efficiency_table,
                         optimal_checkpoint_interval_s,
                         search_checkpoint_interval, simulate_trainer_plan,
                         sustainability_ratios)


def _ledger_dump(led: GoodputLedger):
    return [(e.kind, round(e.seconds, 9), e.steps) for e in led.events]


# ------------------------------------------------------------ event engine


def test_event_engine_deterministic_order():
    def fill(eng):
        eng.schedule_at(5.0, "a")
        eng.schedule_at(1.0, "b")
        eng.schedule_at(5.0, "c")  # tie with "a": insertion order wins
        eng.schedule_at(3.0, "d", x=1)
        return [(e.time, e.kind) for e in eng.drain_until(10.0)]

    assert fill(EventEngine(0)) == fill(EventEngine(0)) == [
        (1.0, "b"), (3.0, "d"), (5.0, "a"), (5.0, "c")]


def test_event_engine_cancel_and_horizon():
    eng = EventEngine(0)
    ev = eng.schedule_at(2.0, "x")
    eng.schedule_at(4.0, "y")
    eng.schedule_at(20.0, "z")
    eng.cancel(ev)
    got = [e.kind for e in eng.drain_until(10.0)]
    assert got == ["y"]
    assert eng.now == 10.0
    assert eng.peek_time() == 20.0  # beyond-horizon event still queued


def test_event_engine_rejects_past():
    eng = EventEngine(0)
    eng.schedule_at(5.0, "a")
    assert eng.pop().kind == "a"
    with pytest.raises(ValueError):
        eng.schedule_at(1.0, "late")


# -------------------------------------------------- deterministic failure plan


def test_planned_failures_reproduce_trainer_grammar():
    """Hand-derived ResilientTrainer event grammar for ckpt_every=6,
    failures at steps 9 and 14, 18 steps total."""
    led = simulate_trainer_plan(total_steps=18, checkpoint_every=6,
                                failures={9: 0, 14: 1})
    assert led.structure() == [
        ("idle", 0), ("steps", 6), ("idle", 0), ("steps", 3),
        ("detect", 0), ("restore", 0), ("rework", 3),
        ("steps", 3), ("idle", 0), ("steps", 2),
        ("detect", 0), ("restore", 0), ("rework", 2),
        ("steps", 4), ("idle", 0)]
    assert led.effective_steps == 18


def test_sim_determinism_bitwise():
    """Same seed, same config -> identical ledgers, stats and trace."""

    def build():
        cfg = FleetConfig(tpu="ironwood", total_cubes=40,
                          host_mtbf_hours=500.0, repair_hours=2.0,
                          sdc=SDCRateModel(rate_per_chip_hour=2e-5,
                                           screen_interval_s=300.0),
                          seed=7)
        jobs = [JobSpec(name=f"j{i}", chips=512, total_steps=10**9,
                        step_time_s=1.5, checkpoint_every_steps=200)
                for i in range(3)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(86400.0)
        return sim

    a, b = build(), build()
    assert a.stats == b.stats
    for name in a.jobs:
        assert _ledger_dump(a.jobs[name].ledger) == \
            _ledger_dump(b.jobs[name].ledger)
    assert a.trace.chrome_trace() == b.trace.chrome_trace()
    assert a.stats["cube_failures"] > 0  # scenario actually exercised


@hypothesis.given(
    seed=st.integers(min_value=0, max_value=10_000),
    mtbf=st.floats(min_value=50.0, max_value=5000.0),
    njobs=st.integers(min_value=1, max_value=5),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_goodput_bounds_and_invariants_property(seed, mtbf, njobs):
    """Whatever the failure pattern: every goodput stays in [0, 1], the
    scheduler's no-shared-cube invariant holds through every event
    (checked inside run()), and effective steps never exceed the total."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=24,
                      host_mtbf_hours=mtbf, repair_hours=1.0, seed=seed)
    jobs = [JobSpec(name=f"j{i}", chips=256, total_steps=2000,
                    step_time_s=1.0, checkpoint_every_steps=100)
            for i in range(njobs)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(40_000.0)  # check_invariants=True asserts after every event
    for job in sim.jobs.values():
        assert 0.0 <= job.ledger.goodput <= 1.0
        assert job.ledger.effective_steps <= job.spec.total_steps
        if job.state == "done":
            # wall-clock conservation: the ledger partitions exactly the
            # arrival-to-completion span, nothing dropped or doubled
            assert job.ledger.total_seconds == pytest.approx(
                job.completed_at - job.spec.arrival_s)
    fs = sim.fleet_summary()
    assert 0.0 <= fs["min_goodput"] <= 1.0


def test_reconfigs_do_not_starve_with_spares():
    """Ironwood headline: four 2K-chip jobs on 144 cubes ride through
    failures on 16 spares — substitutions happen, nobody starves."""
    cfg = FleetConfig(tpu="ironwood", total_cubes=144,
                      host_mtbf_hours=2000.0, repair_hours=4.0, seed=3)
    jobs = [JobSpec(name=f"job{i}", chips=2048, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=600)
            for i in range(4)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(3 * 86400.0)
    assert sim.sched.reconfig_count > 0
    assert sim.stats["starvations"] == 0
    assert all(j.state == "running" for j in sim.jobs.values())
    assert sim.fleet_summary()["min_goodput"] > 0.9


def test_fail_host_maps_to_owning_cube():
    """Host-granular failures (the paper's primary hazard) map out the
    whole cube the host serves."""
    from repro.core.ocs import OCSPodScheduler
    sched = OCSPodScheduler(total_cubes=4)
    sched.allocate("j", 128)  # cubes 0, 1
    cube, impacted = sched.fail_host(20)  # 16 hosts/cube -> cube 1
    assert (cube, impacted) == (1, "j")
    cube, impacted = sched.fail_host(3 * 16 + 5)  # idle cube 3
    assert (cube, impacted) == (3, None)
    with pytest.raises(ValueError):
        sched.fail_host(4 * 16)


def test_starvation_queues_and_resumes():
    """With zero spares, the first failure starves the job; the repair
    re-admits it with a restore + rework charge."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=2,
                      host_mtbf_hours=None, repair_hours=1.0)
    job = JobSpec(name="j", chips=128, total_steps=10_000, step_time_s=1.0,
                  checkpoint_every_steps=100, failure_steps=((250, 0),))
    sim = FleetSimulator(cfg, [job])
    sim.run(20_000.0)
    jr = sim.jobs["j"]
    assert sim.stats["starvations"] == 1
    assert jr.state == "done"
    kinds = [k for k, _ in jr.ledger.structure()]
    assert "detect" in kinds and "restore" in kinds and "idle" in kinds
    t = jr.ledger.totals()
    # queued from the end of detection until the repair: no overlap
    assert t["idle"] == pytest.approx(3600.0 - sim.cfg.detect_s)
    assert t["rework"] == pytest.approx(50.0)  # 250 - ckpt@200
    # wall-clock conservation: the ledger partitions exactly the span
    # from arrival to completion, with nothing double-charged
    assert jr.ledger.total_seconds == pytest.approx(jr.completed_at)


def test_sdc_starvation_charges_restore_once():
    """Regression: an SDC rollback that starves (no spares) must charge
    detect at the event and restore+rework exactly once, at
    re-admission — and the ledger must still partition wall time."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=2, host_mtbf_hours=None,
                      repair_hours=0.5,
                      sdc=SDCRateModel(rate_per_chip_hour=0.05,
                                       screen_interval_s=300.0,
                                       screen_coverage=1.0),
                      seed=4)
    job = JobSpec(name="j", chips=128, total_steps=30_000, step_time_s=1.0,
                  checkpoint_every_steps=100)
    sim = FleetSimulator(cfg, [job])
    sim.run(200_000.0)
    jr = sim.jobs["j"]
    assert sim.stats["sdc_detections"] >= 1
    assert sim.stats["starvations"] == sim.stats["sdc_detections"]
    restores = [e for e in jr.ledger.events if e.kind == "restore"]
    assert len(restores) == sim.stats["sdc_detections"]
    if jr.state == "done":
        assert jr.ledger.total_seconds == pytest.approx(jr.completed_at)


def test_sdc_rolls_back_past_poisoned_checkpoints():
    """A corruption detected late must rework back to the last snapshot
    BEFORE the corruption, not merely the last snapshot."""
    cfg = FleetConfig(tpu="ironwood", total_cubes=4, host_mtbf_hours=None,
                      sdc=SDCRateModel(rate_per_chip_hour=0.5,
                                       screen_interval_s=400.0,
                                       screen_coverage=0.5),
                      seed=11)
    job = JobSpec(name="j", chips=128, total_steps=100_000,
                  step_time_s=1.0, checkpoint_every_steps=100)
    sim = FleetSimulator(cfg, [job])
    sim.run(50_000.0)
    assert sim.stats["sdc_detections"] >= 1
    jr = sim.jobs["j"]
    reworks = [e for e in jr.ledger.events if e.kind == "rework"]
    assert reworks, "sdc detection must charge rework"
    # at least one rollback crossed a checkpoint boundary (rework longer
    # than one full interval means a later snapshot was poisoned)
    assert any(e.steps > 100 for e in reworks)


def test_contiguous_pod_fares_worse_than_ocs():
    """Same fleet, same seed: pre-OCS (contiguous, no substitution)
    scheduling loses more goodput than the OCS pod — the paper's
    resilience argument, measured."""

    def run(contiguous):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=27,
                          host_mtbf_hours=300.0, repair_hours=2.0,
                          contiguous=contiguous, seed=5)
        jobs = [JobSpec(name=f"j{i}", chips=256, total_steps=10**9,
                        step_time_s=1.0, checkpoint_every_steps=300)
                for i in range(4)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(2 * 86400.0)
        return sim

    ocs, contig = run(False), run(True)
    assert ocs.fleet_summary()["mean_goodput"] > \
        contig.fleet_summary()["mean_goodput"]
    assert contig.stats["starvations"] > 0  # no substitution pre-OCS
    assert ocs.sched.reconfig_count > 0


def test_sdc_survives_failstop_restore_from_poisoned_ckpt():
    """Regression: a fail-stop failure between a corruption and its
    detection restores from a snapshot that may postdate the corruption;
    the corruption then survives the restore and its detection must be
    re-armed, not silently dropped."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=None,
                      detect_s=1.0, restore_s=1.0, reconfig_s=0.0,
                      sdc=SDCRateModel(rate_per_chip_hour=3600.0 / 64,
                                       screen_interval_s=5000.0,
                                       screen_coverage=1.0),
                      seed=0)
    # corruption lands within the first ~second of stepping; the planned
    # fail-stop at step 300 restores from ckpt@200 (poisoned: corruption
    # happened before it); detection would only fire at ~5000s
    job = JobSpec(name="j", chips=64, total_steps=20_000, step_time_s=1.0,
                  checkpoint_every_steps=200,
                  failure_steps=((300, -1),))
    sim = FleetSimulator(cfg, [job])
    sim.run(100_000.0)
    jr = sim.jobs["j"]
    assert sim.stats["sdc_corruptions"] >= 1
    assert sim.stats["sdc_detections"] >= 1, \
        "corruption must still be detected after the fail-stop restore"
    assert jr.state == "done"
    assert jr.ledger.total_seconds == pytest.approx(jr.completed_at)


def test_planned_failure_on_foreign_cube_interrupts_owner():
    """Regression: a plan naming another job's cube must fail the real
    owner too, not leave it running on a dead cube."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=None)
    # j0 owns cubes {0,1}, j1 owns {2,3}; j0's plan kills cube 2
    jobs = [JobSpec(name="j0", chips=128, total_steps=1000,
                    step_time_s=1.0, checkpoint_every_steps=100,
                    failure_steps=((500, 2),)),
            JobSpec(name="j1", chips=128, total_steps=1000,
                    step_time_s=1.0, checkpoint_every_steps=100)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(10_000.0)
    assert sim.jobs["j0"].state == "done"
    assert sim.jobs["j1"].state == "done"
    # both jobs observed the failure: the owner via impact, the planner
    # via driver semantics
    for name in ("j0", "j1"):
        kinds = [k for k, _ in sim.jobs[name].ledger.structure()]
        assert "detect" in kinds and "restore" in kinds
    assert sim.sched.reconfig_count == 1  # only the owner resubstitutes
    assert 2 in sim.sched.failed_cubes  # repair (4 h) is past the horizon


def test_bridge_horizon_covers_dense_failure_plans():
    """Regression: 3 failures with checkpoint_every > total_steps rework
    nearly the whole history each time; the sim horizon must cover it."""
    led = simulate_trainer_plan(total_steps=18, checkpoint_every=100,
                                failures={15: 0, 16: 1, 17: 2})
    assert led.effective_steps == 18
    rework = sum(s for k, s in led.structure() if k == "rework")
    assert rework == 15 + 16 + 17  # restore always from the bootstrap


# ------------------------------------------------------- checkpoint policy


def test_checkpoint_interval_search_matches_young_daly():
    mtbf_h, write_s = 6.0, 30.0
    yd = optimal_checkpoint_interval_s(mtbf_h * 3600.0, write_s)
    best_t, best_g = search_checkpoint_interval(
        mtbf_hours=mtbf_h, detect_s=0.0, restore_s=0.0,
        checkpoint_write_s=write_s)
    assert best_t == pytest.approx(yd, rel=0.15)
    assert 0.0 < best_g < 1.0
    # the searched optimum beats a clearly-off interval
    off = modeled_goodput(mtbf_hours=mtbf_h, detect_s=0.0, restore_s=0.0,
                          checkpoint_interval_s=yd * 20,
                          checkpoint_write_s=write_s)
    assert best_g > off


# ------------------------------------------------------------ power/carbon


def test_sustainability_ratio_matches_paper():
    r = sustainability_ratios()
    # anchored-TDP derivation must land on the paper's ~29.3x perf/Watt
    assert r["joules_per_flop_improvement_x"] == \
        pytest.approx(r["paper_perf_per_watt_x"], rel=0.02)
    assert r["co2e_per_flop_improvement_x"] == \
        r["joules_per_flop_improvement_x"]
    table = generation_efficiency_table()
    names = [s.name for s in hwspec.GENERATIONS]
    vals = [table[n] for n in names]
    assert vals == sorted(vals, reverse=True), \
        "J/FLOP must improve monotonically v2 -> Ironwood"


def test_power_model_integrates_ledger():
    led = GoodputLedger()
    led.record_steps(3600.0, steps=1800)
    led.record_restore(3600.0)
    pm = PowerModel(hwspec.get("ironwood"), mfu=0.5,
                    idle_power_fraction=0.2)
    s = pm.job_summary(led, chips=256)
    chip_w = hwspec.chip_tdp_watts(hwspec.get("ironwood"))
    assert s["energy_j"] == pytest.approx(
        256 * chip_w * 3600.0 * (1.0 + 0.2))
    assert s["effective_eflops"] == pytest.approx(
        3600.0 * 256 * hwspec.get("ironwood").peak_tflops * 1e12 * 0.5
        / 1e18)
    assert s["gco2e_total"] > s["gco2e_operational"] > 0.0


def test_tdp_anchor_reproduces_relative_row():
    v2 = hwspec.pod_tdp_watts(hwspec.TPU_V2)
    iw = hwspec.pod_tdp_watts(hwspec.IRONWOOD)
    assert v2 == pytest.approx(256 * 280.0)
    assert iw / v2 == pytest.approx(hwspec.IRONWOOD.rel_pod_tdp)
    assert hwspec.pod_tdp_watts(hwspec.TPU_V5E) is None


# ------------------------------------------------------------------- trace


def test_chrome_trace_export(tmp_path):
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=100.0,
                      seed=2)
    sim = FleetSimulator(cfg, [JobSpec(name="j", chips=256,
                                       total_steps=5000, step_time_s=1.0,
                                       checkpoint_every_steps=500)])
    sim.run(20_000.0)
    path = tmp_path / "trace.json"
    sim.trace.write(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert all({"ph", "pid", "name"} <= set(e) for e in evs)
    phases = {e["name"] for e in evs if e["ph"] == "X"}
    assert "train" in phases
    insts = {e["name"] for e in evs if e["ph"] == "i"}
    assert {"cube_fail", "ocs_reconfig"} & insts
    # X events carry microsecond ts/dur
    xs = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] > 0 for e in xs)


# ------------------------------------------------------------------ bridge


def test_bridge_sim_matches_resilient_trainer():
    """The acceptance pin: a real ResilientTrainer run and the simulator,
    driven by the same failure plan, produce the same goodput-ledger
    structure event-for-event."""
    from repro.fleet import run_bridge
    out = run_bridge(steps=18, checkpoint_every=6, failures={9: 0, 14: 1})
    assert out["match"], (out["real_structure"], out["sim_structure"])
    assert out["effective_steps"] == 18
    assert out["replay_summary"]["replayed_steps"] == 5  # 3 + 2
    assert 0.0 < out["sim_goodput"] <= 1.0
    assert 0.0 < out["real_goodput"] <= 1.0
