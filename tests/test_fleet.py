"""Fleet simulator: event-engine determinism, goodput bounds, scheduler
invariants through reconfigurations, SDC rollback semantics, elastic
re-scale (shrink-on-starvation, grow-back, grammar stability),
roofline-fed step times, checkpoint-write contention + sim-vs-Young/Daly
interval agreement, power/carbon ratios, Chrome-trace export, and the
sim-vs-ResilientTrainer bridge."""

import json

import pytest
from optional_deps import hypothesis, st  # real or deterministic shim

from repro.core import hwspec
from repro.core.goodput import GoodputLedger, modeled_goodput
from repro.core.sdc import SDCRateModel
from repro.fleet import (GRAMMAR_KINDS, EventEngine, FleetConfig,
                         FleetSimulator, JobSpec, PowerModel,
                         StepTimeModel, TrainWorkload,
                         generation_efficiency_table,
                         generation_step_times, grammar_ok,
                         job_spec_from_roofline,
                         optimal_checkpoint_interval_s,
                         search_checkpoint_interval,
                         sim_checkpoint_interval_sweep,
                         simulate_trainer_plan, sustainability_ratios)


def _ledger_dump(led: GoodputLedger):
    return [(e.kind, round(e.seconds, 9), e.steps) for e in led.events]


# ------------------------------------------------------------ event engine


def test_event_engine_deterministic_order():
    def fill(eng):
        eng.schedule_at(5.0, "a")
        eng.schedule_at(1.0, "b")
        eng.schedule_at(5.0, "c")  # tie with "a": insertion order wins
        eng.schedule_at(3.0, "d", x=1)
        return [(e.time, e.kind) for e in eng.drain_until(10.0)]

    assert fill(EventEngine(0)) == fill(EventEngine(0)) == [
        (1.0, "b"), (3.0, "d"), (5.0, "a"), (5.0, "c")]


def test_event_engine_cancel_and_horizon():
    eng = EventEngine(0)
    ev = eng.schedule_at(2.0, "x")
    eng.schedule_at(4.0, "y")
    eng.schedule_at(20.0, "z")
    eng.cancel(ev)
    got = [e.kind for e in eng.drain_until(10.0)]
    assert got == ["y"]
    assert eng.now == 10.0
    assert eng.peek_time() == 20.0  # beyond-horizon event still queued


def test_event_engine_rejects_past():
    eng = EventEngine(0)
    eng.schedule_at(5.0, "a")
    assert eng.pop().kind == "a"
    with pytest.raises(ValueError):
        eng.schedule_at(1.0, "late")


# -------------------------------------------------- deterministic failure plan


def test_planned_failures_reproduce_trainer_grammar():
    """Hand-derived ResilientTrainer event grammar for ckpt_every=6,
    failures at steps 9 and 14, 18 steps total."""
    led = simulate_trainer_plan(total_steps=18, checkpoint_every=6,
                                failures={9: 0, 14: 1})
    assert led.structure() == [
        ("idle", 0), ("steps", 6), ("idle", 0), ("steps", 3),
        ("detect", 0), ("restore", 0), ("rework", 3),
        ("steps", 3), ("idle", 0), ("steps", 2),
        ("detect", 0), ("restore", 0), ("rework", 2),
        ("steps", 4), ("idle", 0)]
    assert led.effective_steps == 18


def test_sim_determinism_bitwise():
    """Same seed, same config -> identical ledgers, stats and trace."""

    def build():
        cfg = FleetConfig(tpu="ironwood", total_cubes=40,
                          host_mtbf_hours=500.0, repair_hours=2.0,
                          sdc=SDCRateModel(rate_per_chip_hour=2e-5,
                                           screen_interval_s=300.0),
                          seed=7)
        jobs = [JobSpec(name=f"j{i}", chips=512, total_steps=10**9,
                        step_time_s=1.5, checkpoint_every_steps=200)
                for i in range(3)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(86400.0)
        return sim

    a, b = build(), build()
    assert a.stats == b.stats
    for name in a.jobs:
        assert _ledger_dump(a.jobs[name].ledger) == \
            _ledger_dump(b.jobs[name].ledger)
    assert a.trace.chrome_trace() == b.trace.chrome_trace()
    assert a.stats["cube_failures"] > 0  # scenario actually exercised


@hypothesis.given(
    seed=st.integers(min_value=0, max_value=10_000),
    mtbf=st.floats(min_value=50.0, max_value=5000.0),
    njobs=st.integers(min_value=1, max_value=5),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_goodput_bounds_and_invariants_property(seed, mtbf, njobs):
    """Whatever the failure pattern: every goodput stays in [0, 1], the
    scheduler's no-shared-cube invariant holds through every event
    (checked inside run()), and effective steps never exceed the total."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=24,
                      host_mtbf_hours=mtbf, repair_hours=1.0, seed=seed)
    jobs = [JobSpec(name=f"j{i}", chips=256, total_steps=2000,
                    step_time_s=1.0, checkpoint_every_steps=100)
            for i in range(njobs)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(40_000.0)  # check_invariants=True asserts after every event
    for job in sim.jobs.values():
        assert 0.0 <= job.ledger.goodput <= 1.0
        assert job.ledger.effective_steps <= job.spec.total_steps
        if job.state == "done":
            # wall-clock conservation: the ledger partitions exactly the
            # arrival-to-completion span, nothing dropped or doubled
            assert job.ledger.total_seconds == pytest.approx(
                job.completed_at - job.spec.arrival_s)
    fs = sim.fleet_summary()
    assert 0.0 <= fs["min_goodput"] <= 1.0


def test_reconfigs_do_not_starve_with_spares():
    """Ironwood headline: four 2K-chip jobs on 144 cubes ride through
    failures on 16 spares — substitutions happen, nobody starves."""
    cfg = FleetConfig(tpu="ironwood", total_cubes=144,
                      host_mtbf_hours=2000.0, repair_hours=4.0, seed=3)
    jobs = [JobSpec(name=f"job{i}", chips=2048, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=600)
            for i in range(4)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(3 * 86400.0)
    assert sim.sched.reconfig_count > 0
    assert sim.stats["starvations"] == 0
    assert all(j.state == "running" for j in sim.jobs.values())
    assert sim.fleet_summary()["min_goodput"] > 0.9


def test_fail_host_maps_to_owning_cube():
    """Host-granular failures (the paper's primary hazard) map out the
    whole cube the host serves."""
    from repro.core.ocs import OCSPodScheduler
    sched = OCSPodScheduler(total_cubes=4)
    sched.allocate("j", 128)  # cubes 0, 1
    cube, impacted = sched.fail_host(20)  # 16 hosts/cube -> cube 1
    assert (cube, impacted) == (1, "j")
    cube, impacted = sched.fail_host(3 * 16 + 5)  # idle cube 3
    assert (cube, impacted) == (3, None)
    with pytest.raises(ValueError):
        sched.fail_host(4 * 16)


def test_starvation_queues_and_resumes():
    """With zero spares, the first failure starves the job; the repair
    re-admits it with a restore + rework charge."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=2,
                      host_mtbf_hours=None, repair_hours=1.0)
    job = JobSpec(name="j", chips=128, total_steps=10_000, step_time_s=1.0,
                  checkpoint_every_steps=100, failure_steps=((250, 0),))
    sim = FleetSimulator(cfg, [job])
    sim.run(20_000.0)
    jr = sim.jobs["j"]
    assert sim.stats["starvations"] == 1
    assert jr.state == "done"
    kinds = [k for k, _ in jr.ledger.structure()]
    assert "detect" in kinds and "restore" in kinds and "idle" in kinds
    t = jr.ledger.totals()
    # queued from the end of detection until the repair: no overlap
    assert t["idle"] == pytest.approx(3600.0 - sim.cfg.detect_s)
    assert t["rework"] == pytest.approx(50.0)  # 250 - ckpt@200
    # wall-clock conservation: the ledger partitions exactly the span
    # from arrival to completion, with nothing double-charged
    assert jr.ledger.total_seconds == pytest.approx(jr.completed_at)


def test_sdc_starvation_charges_restore_once():
    """Regression: an SDC rollback that starves (no spares) must charge
    detect at the event and restore+rework exactly once, at
    re-admission — and the ledger must still partition wall time."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=2, host_mtbf_hours=None,
                      repair_hours=0.5,
                      sdc=SDCRateModel(rate_per_chip_hour=0.05,
                                       screen_interval_s=300.0,
                                       screen_coverage=1.0),
                      seed=4)
    job = JobSpec(name="j", chips=128, total_steps=30_000, step_time_s=1.0,
                  checkpoint_every_steps=100)
    sim = FleetSimulator(cfg, [job])
    sim.run(200_000.0)
    jr = sim.jobs["j"]
    assert sim.stats["sdc_detections"] >= 1
    assert sim.stats["starvations"] == sim.stats["sdc_detections"]
    restores = [e for e in jr.ledger.events if e.kind == "restore"]
    assert len(restores) == sim.stats["sdc_detections"]
    if jr.state == "done":
        assert jr.ledger.total_seconds == pytest.approx(jr.completed_at)


def test_sdc_rolls_back_past_poisoned_checkpoints():
    """A corruption detected late must rework back to the last snapshot
    BEFORE the corruption, not merely the last snapshot."""
    cfg = FleetConfig(tpu="ironwood", total_cubes=4, host_mtbf_hours=None,
                      sdc=SDCRateModel(rate_per_chip_hour=0.5,
                                       screen_interval_s=400.0,
                                       screen_coverage=0.5),
                      seed=11)
    job = JobSpec(name="j", chips=128, total_steps=100_000,
                  step_time_s=1.0, checkpoint_every_steps=100)
    sim = FleetSimulator(cfg, [job])
    sim.run(50_000.0)
    assert sim.stats["sdc_detections"] >= 1
    jr = sim.jobs["j"]
    reworks = [e for e in jr.ledger.events if e.kind == "rework"]
    assert reworks, "sdc detection must charge rework"
    # at least one rollback crossed a checkpoint boundary (rework longer
    # than one full interval means a later snapshot was poisoned)
    assert any(e.steps > 100 for e in reworks)


def test_contiguous_pod_fares_worse_than_ocs():
    """Same fleet, same seed: pre-OCS (contiguous, no substitution)
    scheduling loses more goodput than the OCS pod — the paper's
    resilience argument, measured."""

    def run(contiguous):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=27,
                          host_mtbf_hours=300.0, repair_hours=2.0,
                          contiguous=contiguous, seed=5)
        jobs = [JobSpec(name=f"j{i}", chips=256, total_steps=10**9,
                        step_time_s=1.0, checkpoint_every_steps=300)
                for i in range(4)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(2 * 86400.0)
        return sim

    ocs, contig = run(False), run(True)
    assert ocs.fleet_summary()["mean_goodput"] > \
        contig.fleet_summary()["mean_goodput"]
    assert contig.stats["starvations"] > 0  # no substitution pre-OCS
    assert ocs.sched.reconfig_count > 0


def test_sdc_survives_failstop_restore_from_poisoned_ckpt():
    """Regression: a fail-stop failure between a corruption and its
    detection restores from a snapshot that may postdate the corruption;
    the corruption then survives the restore and its detection must be
    re-armed, not silently dropped."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=None,
                      detect_s=1.0, restore_s=1.0, reconfig_s=0.0,
                      sdc=SDCRateModel(rate_per_chip_hour=3600.0 / 64,
                                       screen_interval_s=5000.0,
                                       screen_coverage=1.0),
                      seed=0)
    # corruption lands within the first ~second of stepping; the planned
    # fail-stop at step 300 restores from ckpt@200 (poisoned: corruption
    # happened before it); detection would only fire at ~5000s
    job = JobSpec(name="j", chips=64, total_steps=20_000, step_time_s=1.0,
                  checkpoint_every_steps=200,
                  failure_steps=((300, -1),))
    sim = FleetSimulator(cfg, [job])
    sim.run(100_000.0)
    jr = sim.jobs["j"]
    assert sim.stats["sdc_corruptions"] >= 1
    assert sim.stats["sdc_detections"] >= 1, \
        "corruption must still be detected after the fail-stop restore"
    assert jr.state == "done"
    assert jr.ledger.total_seconds == pytest.approx(jr.completed_at)


def test_planned_failure_on_foreign_cube_interrupts_owner():
    """Regression: a plan naming another job's cube must fail the real
    owner too, not leave it running on a dead cube."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=None)
    # j0 owns cubes {0,1}, j1 owns {2,3}; j0's plan kills cube 2
    jobs = [JobSpec(name="j0", chips=128, total_steps=1000,
                    step_time_s=1.0, checkpoint_every_steps=100,
                    failure_steps=((500, 2),)),
            JobSpec(name="j1", chips=128, total_steps=1000,
                    step_time_s=1.0, checkpoint_every_steps=100)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(10_000.0)
    assert sim.jobs["j0"].state == "done"
    assert sim.jobs["j1"].state == "done"
    # both jobs observed the failure: the owner via impact, the planner
    # via driver semantics
    for name in ("j0", "j1"):
        kinds = [k for k, _ in sim.jobs[name].ledger.structure()]
        assert "detect" in kinds and "restore" in kinds
    assert sim.sched.reconfig_count == 1  # only the owner resubstitutes
    assert 2 in sim.sched.failed_cubes  # repair (4 h) is past the horizon


def test_bridge_horizon_covers_dense_failure_plans():
    """Regression: 3 failures with checkpoint_every > total_steps rework
    nearly the whole history each time; the sim horizon must cover it."""
    led = simulate_trainer_plan(total_steps=18, checkpoint_every=100,
                                failures={15: 0, 16: 1, 17: 2})
    assert led.effective_steps == 18
    rework = sum(s for k, s in led.structure() if k == "rework")
    assert rework == 15 + 16 + 17  # restore always from the bootstrap


# ------------------------------------------------------ elastic re-scale


def _elastic_scenario(policy):
    """j0 (3 cubes) loses a cube at step 1000 on a spare-less pod; the
    2 h repair either re-admits it (queue) or grows it back (shrink)."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=4, host_mtbf_hours=None,
                      repair_hours=2.0)
    jobs = [JobSpec(name="j0", chips=3 * 64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=300,
                    scale_policy=policy,
                    min_cubes=1 if policy == "shrink" else 0,
                    failure_steps=((1000, -1),)),
            JobSpec(name="j1", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=300)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(4 * 3600.0)
    return sim


def test_elastic_shrink_beats_queue_same_trace():
    """The paper's "reschedule at smaller scale" arm: on the identical
    deterministic failure trace, shrinking wins on goodput AND steps."""
    queue, shrink = _elastic_scenario("queue"), _elastic_scenario("shrink")
    qj, sj = queue.jobs["j0"], shrink.jobs["j0"]
    assert sj.ledger.goodput > qj.ledger.goodput
    assert sj.base_step > qj.base_step
    assert queue.stats["starvations"] == 1 and queue.stats["rescales"] == 0
    assert shrink.stats["starvations"] == 0 and shrink.stats["rescales"] == 1


def test_grow_back_after_repair():
    """The shrunken job returns to full size when the repair frees the
    cube: graceful snapshot (exactly one rework event — the shrink's),
    full-speed stepping afterwards, wall clock still partitioned."""
    sim = _elastic_scenario("shrink")
    j0 = sim.jobs["j0"]
    assert j0.rescales == 1 and j0.grow_backs == 1
    assert j0.cubes == j0.spec.full_cubes == 3
    assert j0.step_time_s == pytest.approx(1.0)  # back to full speed
    reworks = [e for e in j0.ledger.events if e.kind == "rework"]
    assert len(reworks) == 1  # shrink reworks; grow-back must not
    # shrink ran 2 of 3 cubes: rework priced at the shrunken step time
    assert reworks[0].seconds == pytest.approx(reworks[0].steps * 1.5)
    for jr in sim.jobs.values():  # nothing dropped or double-charged
        assert jr.ledger.total_seconds == pytest.approx(4 * 3600.0)


def test_elastic_rescale_deterministic():
    """Same seed, stochastic failures, shrink policy -> bitwise-identical
    ledgers, stats and trace (the event-sequence determinism pin)."""

    def build():
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=10,
                          host_mtbf_hours=100.0, repair_hours=6.0, seed=13)
        jobs = [JobSpec(name=f"j{i}", chips=3 * 64, total_steps=10**9,
                        step_time_s=1.0, checkpoint_every_steps=200,
                        scale_policy="shrink", min_cubes=1)
                for i in range(3)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(2 * 86400.0)
        return sim

    a, b = build(), build()
    assert a.stats == b.stats
    for name in a.jobs:
        assert _ledger_dump(a.jobs[name].ledger) == \
            _ledger_dump(b.jobs[name].ledger)
    assert a.trace.chrome_trace() == b.trace.chrome_trace()
    assert a.stats["rescales"] > 0  # the elastic arm actually fired


def test_elastic_ledger_grammar_stable():
    """Bridge contract: re-scale events never invent ledger vocabulary —
    every event of an elastic run speaks the pinned five kinds."""
    sim = _elastic_scenario("shrink")
    assert set(GRAMMAR_KINDS) == {"steps", "rework", "detect", "restore",
                                  "idle"}
    for jr in sim.jobs.values():
        assert grammar_ok(jr.ledger)
        assert all(k in GRAMMAR_KINDS for k, _ in jr.ledger.structure())


def test_elastic_admission_shrinks_and_respects_min_cubes():
    """A job arriving into a too-small pod admits at the largest
    schedulable slice >= min_cubes; below the floor it queues."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=2, host_mtbf_hours=None)
    ok = JobSpec(name="fits", chips=4 * 64, total_steps=1000,
                 step_time_s=1.0, scale_policy="shrink", min_cubes=2)
    sim = FleetSimulator(cfg, [ok])
    sim.run(10.0)
    jr = sim.jobs["fits"]
    assert jr.state == "running" and jr.cubes == 2
    assert jr.step_time_s == pytest.approx(2.0)  # 4 cubes' work on 2
    assert jr.rescales == 1

    floor = JobSpec(name="floor", chips=4 * 64, total_steps=1000,
                    step_time_s=1.0, scale_policy="shrink", min_cubes=3)
    sim = FleetSimulator(cfg, [floor])
    sim.run(10.0)
    assert sim.jobs["floor"].state == "queued"


def test_scale_policy_validation():
    with pytest.raises(ValueError):
        JobSpec(name="j", chips=64, total_steps=10, scale_policy="grow")
    with pytest.raises(ValueError):
        JobSpec(name="j", chips=64, total_steps=10, min_cubes=5)  # > full
    j = JobSpec(name="j", chips=2 * 64, total_steps=10,
                scale_policy="shrink")
    assert j.min_cubes == 1  # shrink defaults the floor to one cube


def test_ocs_grow_and_max_slice_hooks():
    from repro.core.ocs import OCSPodScheduler
    sched = OCSPodScheduler(total_cubes=6)
    sched.allocate("j", 2 * 64)
    assert sched.max_slice_cubes(10) == 4  # capped by idle cubes
    grown = sched.grow("j", 2)
    assert grown is not None and len(grown.cubes) == 4
    assert sched.spare_cubes() == 2
    sched.check_invariants()
    assert sched.grow("j", 3) is None  # only 2 idle left
    with pytest.raises(KeyError):
        sched.grow("nope", 1)
    # pre-OCS pods cannot stitch new cubes into a block
    contig = OCSPodScheduler(total_cubes=8, contiguous=True)
    contig.allocate("j", 2 * 64)
    assert contig.grow("j", 1) is None
    assert contig.max_slice_cubes(8) <= 6


# ------------------------------------------------- roofline-fed step times


def test_step_time_model_tracks_table1_anchors():
    """Per-generation validation: the same workload gets monotonically
    faster v2 -> Ironwood, and the total speedup lands between the
    Table-1 HBM-bandwidth and peak-bf16 ratios (the step is a mix of
    memory, compute and collective terms, so it can't beat peak)."""
    wl = TrainWorkload(n_params=70e9, tokens_per_step=4096 * 4096)
    times = generation_step_times(wl, cubes=8)
    names = [s.name for s in hwspec.GENERATIONS]
    vals = [times[n] for n in names]
    assert vals == sorted(vals, reverse=True)
    ss = hwspec.scaling_summary()
    speedup = times["tpu_v2"] / times["ironwood"]
    assert ss["hbm_bandwidth_x"] <= speedup <= ss["node_peak_bf16_x"] * 1.02


def test_step_time_model_scaling_curve():
    """The elastic arm's curve: more cubes never slower (up to the ring
    factor), ideal-linear while compute-bound, flattening into the
    collective floor — so shrinking a big slice costs less than linear."""
    wl = TrainWorkload(n_params=70e9, tokens_per_step=4096 * 4096)
    m = StepTimeModel("tpu_v4", wl)
    sizes = (4, 8, 16, 32, 64, 128, 256)
    curve = [m(c) for c in sizes]
    assert all(a >= b * (1 - 1e-3) for a, b in zip(curve, curve[1:]))
    assert curve[0] / curve[1] == pytest.approx(2.0, rel=0.01)  # linear
    assert curve[-2] / curve[-1] < 1.5  # collective floor
    assert m.report(256).bound == "collective"
    assert m.report(4).bound == "compute"


def test_job_spec_from_roofline_drives_elastic_sim():
    """A roofline-priced JobSpec: full-size step time equals the model's,
    shrinking follows the curve inside the simulator."""
    wl = TrainWorkload(n_params=8e9, tokens_per_step=1024 * 1024)
    spec = job_spec_from_roofline(
        "r", "tpu_v4", wl, chips=3 * 64, total_steps=10**9,
        checkpoint_every_steps=500, scale_policy="shrink", min_cubes=1)
    m = StepTimeModel("tpu_v4", wl)
    assert spec.step_time_s == pytest.approx(m(3))
    assert spec.step_time_for(2) == pytest.approx(m(2))
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=4, host_mtbf_hours=None,
                      repair_hours=50.0)  # no repair inside the horizon
    spec = JobSpec(**{**spec.__dict__, "failure_steps": ((100, -1),)})
    sim = FleetSimulator(cfg, [spec, JobSpec(
        name="filler", chips=64, total_steps=10**9, step_time_s=1.0)])
    sim.run(m(3) * 100 + 40_000.0)
    jr = sim.jobs["r"]
    assert jr.cubes == 2 and jr.rescales == 1
    assert jr.step_time_s == pytest.approx(m(2))


# ------------------------------------- checkpoint writes: stalls, contention


def test_sync_ckpt_write_stalls_and_interval_tradeoff():
    """Synchronous writes charge idle stalls per snapshot; halving the
    interval doubles the write overhead (the Young/Daly tension the
    sweep optimizes)."""

    def goodput(every):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=2,
                          host_mtbf_hours=None, ckpt_write_s=30.0)
        job = JobSpec(name="j", chips=64, total_steps=10**9,
                      step_time_s=1.0, checkpoint_every_steps=every)
        sim = FleetSimulator(cfg, [job])
        sim.run(40_000.0)
        jr = sim.jobs["j"]
        stalls = [e for e in jr.ledger.events
                  if e.kind == "idle" and e.note.startswith("ckpt write")]
        assert stalls and all(e.seconds == pytest.approx(30.0)
                              for e in stalls)
        # stalls are booked at write start (same convention as
        # detect/restore), so a write straddling the horizon may overhang
        # it by at most one stall
        assert 40_000.0 <= jr.ledger.total_seconds <= 40_000.0 + 30.0
        return jr.ledger.goodput

    # failure-free: longer intervals strictly win (only write cost)
    assert goodput(200) < goodput(400) < goodput(800)


def test_ckpt_write_contention_multiplies_stall():
    """Two jobs on the same cadence: the second write to start pays the
    shared-bandwidth factor (2x) at the first collision."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=4, host_mtbf_hours=None,
                      ckpt_write_s=20.0)
    jobs = [JobSpec(name=f"j{i}", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=100)
            for i in range(2)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(150.0)
    stalls = {n: [e.seconds for e in j.ledger.events
                  if e.kind == "idle" and e.note.startswith("ckpt write")]
              for n, j in sim.jobs.items()}
    assert stalls["j0"] == [pytest.approx(20.0)]
    assert stalls["j1"] == [pytest.approx(40.0)]  # started mid-j0-write


def test_failure_mid_write_rolls_back_to_previous_snapshot():
    """Durability: a snapshot only counts once its write completes. j1's
    planned failure kills j0's cube at t=120, mid j0's write of the
    step-100 snapshot -> j0 reworks all 100 steps from the bootstrap."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=3, host_mtbf_hours=None,
                      detect_s=1.0, restore_s=1.0, reconfig_s=0.0,
                      ckpt_write_s=50.0)
    jobs = [JobSpec(name="j0", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=100),
            JobSpec(name="j1", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=10**8,
                    failure_steps=((120, 0),))]  # cube 0 is j0's
    sim = FleetSimulator(cfg, jobs)
    sim.run(500.0)
    j0 = sim.jobs["j0"]
    reworks = [e for e in j0.ledger.events if e.kind == "rework"]
    assert reworks and reworks[0].steps == 100  # not 0: write was lost
    # control: the same failure *after* the write completes reworks only
    # the steps past the (now durable) snapshot
    jobs[1] = JobSpec(name="j1", chips=64, total_steps=10**9,
                      step_time_s=1.0, checkpoint_every_steps=10**8,
                      failure_steps=((170, 0),))
    sim = FleetSimulator(cfg, jobs)
    sim.run(500.0)
    j0 = sim.jobs["j0"]
    reworks = [e for e in j0.ledger.events if e.kind == "rework"]
    assert reworks and 0 < reworks[0].steps <= 30


def test_aborted_write_stops_contending():
    """Regression: a write voided by a failure must release the shared
    filer — a later writer pays the uncontended stall, not 2x."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=4, host_mtbf_hours=None,
                      detect_s=1.0, restore_s=1.0, reconfig_s=0.0,
                      ckpt_write_s=50.0)
    jobs = [JobSpec(name="j0", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=100),
            JobSpec(name="j1", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=10**8,
                    failure_steps=((120, 0),)),  # kills j0 mid-write
            JobSpec(name="j2", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=130)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(140.0)
    # j0's write (100..150) was aborted at t=120; j2's write at t=130
    # must see an idle filer
    stalls = [e.seconds for e in sim.jobs["j2"].ledger.events
              if e.kind == "idle" and e.note.startswith("ckpt write")]
    assert stalls == [pytest.approx(50.0)]


def test_pre_grow_snapshot_contends_and_is_durable_on_completion():
    """The grow-back snapshot is a synchronous write like any other:
    with ckpt_write_s set it stalls the job and only becomes durable at
    completion (ckpt_write_end is armed, last_ckpt_step is not yet)."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=3, host_mtbf_hours=None,
                      repair_hours=1.0, detect_s=1.0, restore_s=1.0,
                      reconfig_s=0.0, ckpt_write_s=40.0)
    job = JobSpec(name="j", chips=3 * 64, total_steps=10**9,
                  step_time_s=1.0, checkpoint_every_steps=10**8,
                  scale_policy="shrink", min_cubes=1,
                  failure_steps=((500, -1),))
    sim = FleetSimulator(cfg, [job])
    sim.run(6000.0)  # repair (and grow-back) lands at t=4100
    jr = sim.jobs["j"]
    assert jr.rescales == 1 and jr.grow_backs == 1
    pre_grow = [e for e in jr.ledger.events
                if e.kind == "idle" and "(pre-grow)" in e.note]
    assert len(pre_grow) == 1
    assert pre_grow[0].seconds >= 40.0  # write stall (+ partial step)
    # the snapshot settled after completion: rollback point advanced
    assert jr.last_ckpt_step > 0 or jr.ckpt_write_end is not None


def test_sim_interval_optimum_matches_model_search():
    """The acceptance pin for layer 3: the simulator's optimal
    checkpoint interval lands within one grid bucket of the
    closed-form ``search_checkpoint_interval`` family optimum."""
    out = sim_checkpoint_interval_sweep(points=7, mean_failures=20)
    assert out["agree_within_one_bucket"], out
    # and the curve is a real hump: the optimum beats both ends
    best = out["sim_goodput"][out["sim_best_index"]]
    assert best > out["sim_goodput"][0]
    assert best > out["sim_goodput"][-1]


# --------------------------------------------------- incremental deployment


def test_incremental_install_admits_jobs_as_cubes_land():
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=None,
                      install_schedule=((0.0, 4), (1000.0, 8)))
    jobs = [JobSpec(name=f"j{i}", chips=4 * 64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=500)
            for i in range(2)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(2000.0)
    assert sim.jobs["j0"].first_admitted_at == pytest.approx(0.0)
    assert sim.jobs["j1"].first_admitted_at == pytest.approx(1000.0)
    with pytest.raises(ValueError):
        FleetConfig(install_schedule=((0.0, 4), (10.0, 2)))  # shrinking
    with pytest.raises(ValueError):
        FleetConfig(total_cubes=4, install_schedule=((0.0, 8),))


# ------------------------------------------------------- checkpoint policy


def test_checkpoint_interval_search_matches_young_daly():
    mtbf_h, write_s = 6.0, 30.0
    yd = optimal_checkpoint_interval_s(mtbf_h * 3600.0, write_s)
    best_t, best_g = search_checkpoint_interval(
        mtbf_hours=mtbf_h, detect_s=0.0, restore_s=0.0,
        checkpoint_write_s=write_s)
    assert best_t == pytest.approx(yd, rel=0.15)
    assert 0.0 < best_g < 1.0
    # the searched optimum beats a clearly-off interval
    off = modeled_goodput(mtbf_hours=mtbf_h, detect_s=0.0, restore_s=0.0,
                          checkpoint_interval_s=yd * 20,
                          checkpoint_write_s=write_s)
    assert best_g > off


# ------------------------------------------------------------ power/carbon


def test_sustainability_ratio_matches_paper():
    r = sustainability_ratios()
    # anchored-TDP derivation must land on the paper's ~29.3x perf/Watt
    assert r["joules_per_flop_improvement_x"] == \
        pytest.approx(r["paper_perf_per_watt_x"], rel=0.02)
    assert r["co2e_per_flop_improvement_x"] == \
        r["joules_per_flop_improvement_x"]
    table = generation_efficiency_table()
    names = [s.name for s in hwspec.GENERATIONS]
    vals = [table[n] for n in names]
    assert vals == sorted(vals, reverse=True), \
        "J/FLOP must improve monotonically v2 -> Ironwood"


def test_power_model_integrates_ledger():
    led = GoodputLedger()
    led.record_steps(3600.0, steps=1800)
    led.record_restore(3600.0)
    pm = PowerModel(hwspec.get("ironwood"), mfu=0.5,
                    idle_power_fraction=0.2)
    s = pm.job_summary(led, chips=256)
    chip_w = hwspec.chip_tdp_watts(hwspec.get("ironwood"))
    assert s["energy_j"] == pytest.approx(
        256 * chip_w * 3600.0 * (1.0 + 0.2))
    assert s["effective_eflops"] == pytest.approx(
        3600.0 * 256 * hwspec.get("ironwood").peak_tflops * 1e12 * 0.5
        / 1e18)
    assert s["gco2e_total"] > s["gco2e_operational"] > 0.0


def test_tdp_anchor_reproduces_relative_row():
    v2 = hwspec.pod_tdp_watts(hwspec.TPU_V2)
    iw = hwspec.pod_tdp_watts(hwspec.IRONWOOD)
    assert v2 == pytest.approx(256 * 280.0)
    assert iw / v2 == pytest.approx(hwspec.IRONWOOD.rel_pod_tdp)
    assert hwspec.pod_tdp_watts(hwspec.TPU_V5E) is None


# ------------------------------------------------------------------- trace


def test_chrome_trace_export(tmp_path):
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=8, host_mtbf_hours=100.0,
                      seed=2)
    sim = FleetSimulator(cfg, [JobSpec(name="j", chips=256,
                                       total_steps=5000, step_time_s=1.0,
                                       checkpoint_every_steps=500)])
    sim.run(20_000.0)
    path = tmp_path / "trace.json"
    sim.trace.write(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert all({"ph", "pid", "name"} <= set(e) for e in evs)
    phases = {e["name"] for e in evs if e["ph"] == "X"}
    assert "train" in phases
    insts = {e["name"] for e in evs if e["ph"] == "i"}
    assert {"cube_fail", "ocs_reconfig"} & insts
    # X events carry microsecond ts/dur
    xs = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] > 0 for e in xs)


# ------------------------------------------------------------------ bridge


def test_bridge_sim_matches_resilient_trainer():
    """The acceptance pin: a real ResilientTrainer run and the simulator,
    driven by the same failure plan, produce the same goodput-ledger
    structure event-for-event."""
    from repro.fleet import run_bridge
    out = run_bridge(steps=18, checkpoint_every=6, failures={9: 0, 14: 1})
    assert out["match"], (out["real_structure"], out["sim_structure"])
    assert out["effective_steps"] == 18
    assert out["replay_summary"]["replayed_steps"] == 5  # 3 + 2
    assert 0.0 < out["sim_goodput"] <= 1.0
    assert 0.0 < out["real_goodput"] <= 1.0
