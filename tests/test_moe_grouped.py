"""Sort-based dropless MoE: dispatch-plan property battery + kernel
parity against the jnp oracle and the dense capacity path.

The grouped pipeline is pure bookkeeping (sort -> pad -> GEMM ->
unpermute) around one kernel, so correctness decomposes into invariants
the property tests pin down exhaustively:

  * the sorted buffer is a padded permutation (every token appears
    exactly k times, pad rows nowhere touched),
  * group offsets are monotone and sum to T*k,
  * unpermute inverts permute,
  * combine weights equal the dense-softmax renormalized top-k,

plus end-to-end parity: grouped(impl=ref|interpret) == capacity
dispatch with an un-droppable buffer (capacity_factor -> inf), in both
bf16/int8 weights and swiglu/gelu stacks, on the mixtral and kimi
smoke configs.
"""

import dataclasses

from optional_deps import hypothesis, st  # real or deterministic shim
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.kernels import moe_gemm, ops as kops, ref
from repro.models.moe import (grouped_combine, grouped_dispatch_plan,
                              grouped_permute, moe_ffn, moe_param_specs,
                              quantize_moe_params)
from repro.models.params import init_params

KEY = jax.random.key(0)


def rnd(i, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)
    return x.astype(dtype)


def random_routing(seed: int, t: int, k: int, e: int):
    """(T, k) expert ids, distinct per token like top-k produces."""
    key = jax.random.fold_in(KEY, seed)
    scores = jax.random.normal(key, (t, e))
    _, idx = jax.lax.top_k(scores, min(k, e))
    return idx.astype(jnp.int32)


# ----------------------------------------------------- plan properties


@hypothesis.given(st.integers(min_value=0, max_value=1 << 20),
                  st.integers(min_value=1, max_value=24),
                  st.integers(min_value=1, max_value=4),
                  st.integers(min_value=1, max_value=8),
                  st.sampled_from([4, 8]))
@hypothesis.settings(max_examples=30, deadline=None)
def test_plan_is_padded_permutation(seed, t, k, e, bm):
    k = min(k, e)
    gate_idx = random_routing(seed, t, k, e)
    plan = grouped_dispatch_plan(gate_idx, n_experts=e, block_m=bm)
    row_src = np.asarray(plan.row_src)
    dest = np.asarray(plan.dest)
    # dest is injective into the padded buffer and row_src inverts it:
    # slot dest[a] holds assignment a's source token.
    assert len(set(dest.tolist())) == t * k
    assert np.all((dest >= 0) & (dest < plan.padded_rows))
    np.testing.assert_array_equal(row_src[dest], np.arange(t * k) // k)
    # every token referenced exactly k times; pad rows are -1
    tokens, counts = np.unique(row_src[row_src >= 0], return_counts=True)
    np.testing.assert_array_equal(tokens, np.arange(t))
    np.testing.assert_array_equal(counts, np.full(t, k))
    assert np.sum(row_src < 0) == plan.padded_rows - t * k


@hypothesis.given(st.integers(min_value=0, max_value=1 << 20),
                  st.integers(min_value=1, max_value=24),
                  st.integers(min_value=1, max_value=4),
                  st.integers(min_value=1, max_value=8),
                  st.sampled_from([4, 8]))
@hypothesis.settings(max_examples=30, deadline=None)
def test_plan_offsets_and_tiles(seed, t, k, e, bm):
    k = min(k, e)
    gate_idx = random_routing(seed, t, k, e)
    plan = grouped_dispatch_plan(gate_idx, n_experts=e, block_m=bm)
    counts = np.asarray(plan.counts)
    offsets = np.asarray(plan.offsets)
    # offsets = monotone cumsum of counts, summing to T*k
    assert offsets.shape == (e + 1,)
    assert np.all(np.diff(offsets) >= 0)
    np.testing.assert_array_equal(np.diff(offsets), counts)
    assert offsets[-1] == t * k
    # padded group starts are block-aligned and ordered
    starts = np.asarray(plan.padded_starts)
    assert np.all(starts % bm == 0)
    assert np.all(np.diff(starts) >= 0)
    # each m-tile is single-expert: every assignment's dest tile carries
    # that assignment's expert id; tiles past the data are the sentinel
    flat_e = np.asarray(gate_idx).reshape(-1)
    tiles = np.asarray(plan.block_experts)
    np.testing.assert_array_equal(tiles[np.asarray(plan.dest) // bm],
                                  flat_e)
    assert np.all((tiles >= -1) & (tiles < e))
    assert (tiles >= 0).sum() == -(-counts // bm).sum()


@hypothesis.given(st.integers(min_value=0, max_value=1 << 20),
                  st.integers(min_value=1, max_value=16),
                  st.integers(min_value=1, max_value=4),
                  st.integers(min_value=1, max_value=8))
@hypothesis.settings(max_examples=25, deadline=None)
def test_unpermute_inverts_permute(seed, t, k, e):
    k = min(k, e)
    d = 16
    gate_idx = random_routing(seed, t, k, e)
    xt = rnd(seed + 1, (t, d))
    plan = grouped_dispatch_plan(gate_idx, n_experts=e, block_m=4)
    xs = grouped_permute(xt, plan, jnp.float32)
    # gathering back through dest recovers each token's row k times
    back = np.asarray(xs)[np.asarray(plan.dest)].reshape(t, k, d)
    np.testing.assert_array_equal(back,
                                  np.repeat(np.asarray(xt)[:, None], k, 1))
    # pad rows stay zero (psum identity under expert parallelism)
    pads = np.asarray(xs)[np.asarray(plan.row_src) < 0]
    np.testing.assert_array_equal(pads, np.zeros_like(pads))
    # combine with uniform gates averages the k copies back to the token
    gate_w = jnp.full((t, k), 1.0 / k)
    out = grouped_combine(xs, plan, gate_w, t, k)
    np.testing.assert_allclose(out, xt, rtol=1e-6, atol=1e-6)


@hypothesis.given(st.integers(min_value=0, max_value=1 << 20),
                  st.integers(min_value=1, max_value=12))
@hypothesis.settings(max_examples=20, deadline=None)
def test_combine_weights_match_dense_softmax(seed, t):
    """Grouped output == sum_k renorm(softmax(logits))[top-k] * expert(x),
    computed densely per token — the routing contract both dispatch
    modes share."""
    d, e, k = 16, 4, 2
    cfg = dataclasses.replace(get_smoke("mixtral_8x22b"), d_model=d,
                              d_ff=24, n_experts=e, experts_per_token=k)
    p = init_params(jax.random.fold_in(KEY, seed), moe_param_specs(cfg))
    x = rnd(seed + 7, (1, t, d))
    out, _ = moe_ffn(p, x, cfg, jnp.float32, dispatch="grouped",
                     impl="ref")
    # dense per-token oracle
    logits = np.asarray(x.reshape(t, d) @ np.asarray(p["router"]))
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gw, gi = jax.lax.top_k(probs, k)
    gw = np.asarray(gw / gw.sum(-1, keepdims=True))
    want = np.zeros((t, d), np.float32)
    from repro.models.ops import swiglu
    for ti in range(t):
        for j in range(k):
            ex = int(gi[ti, j])
            up = x.reshape(t, d)[ti] @ p["w_up"][ex]
            h = swiglu(x.reshape(t, d)[ti] @ p["w_gate"][ex], up)
            want[ti] += gw[ti, j] * np.asarray(h @ p["w_down"][ex])
    np.testing.assert_allclose(out[0], want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t,k,e,case", [
    (6, 2, 4, "all_one"),   # every assignment routed to expert 1
    (3, 2, 8, "t_lt_e"),    # fewer tokens than experts
    (1, 2, 4, "single"),    # T=1
    (1, 1, 1, "minimal"),   # one token, one expert, k=1
])
def test_plan_degenerate_cases(t, k, e, case):
    if case == "all_one":
        gate_idx = jnp.full((t, k), 1, jnp.int32)
    else:
        gate_idx = random_routing(99, t, k, e)
    plan = grouped_dispatch_plan(gate_idx, n_experts=e, block_m=8)
    dest = np.asarray(plan.dest)
    assert len(set(dest.tolist())) == t * k
    np.testing.assert_array_equal(np.asarray(plan.row_src)[dest],
                                  np.arange(t * k) // k)
    assert np.asarray(plan.offsets)[-1] == t * k
    d = 8
    xt = rnd(5, (t, d))
    xs = grouped_permute(xt, plan, jnp.float32)
    out = grouped_combine(xs, plan, jnp.full((t, k), 1.0 / k), t, k)
    np.testing.assert_allclose(out, xt, rtol=1e-6, atol=1e-6)
    if case == "all_one":
        tiles = np.asarray(plan.block_experts)
        assert set(tiles[tiles >= 0].tolist()) == {1}


# ------------------------------------------- kernel == oracle parity


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_scale", [False, True])
def test_grouped_matmul_interpret_matches_ref(dtype, with_scale):
    m, d, f, e, bm = 64, 32, 48, 4, 8
    gids = jnp.array([0, 0, 1, -1, 2, 3, 3, -1], jnp.int32)
    x = rnd(11, (m, d), dtype)
    if with_scale:
        w8 = jnp.clip(jnp.round(rnd(12, (e, d, f)) * 40), -127, 127)
        w = w8.astype(jnp.int8)
        scale = jnp.abs(rnd(13, (e,))) + 0.1
    else:
        w, scale = rnd(12, (e, d, f), dtype), None
    out = moe_gemm.grouped_matmul(x, w, gids, w_scale=scale,
                                  interpret=True, block_f=16)
    want = ref.grouped_matmul_ref(x, w, gids, w_scale=scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    # sentinel tiles are exactly zero in both
    np.testing.assert_array_equal(
        np.asarray(out).reshape(len(gids), bm, f)[np.asarray(gids) < 0],
        0.0)


@pytest.mark.parametrize("arch", ["mixtral_8x22b", "kimi_k2_1t_a32b"])
@pytest.mark.parametrize("act", ["swiglu", "gelu"])
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_grouped_ffn_matches_capacity_dropless(arch, act, impl):
    """Grouped dispatch == capacity dispatch with an un-droppable buffer
    (capacity_factor -> inf == dropless) on real smoke configs."""
    cfg = dataclasses.replace(get_smoke(arch), mlp_act=act)
    p = init_params(jax.random.fold_in(KEY, 3), moe_param_specs(cfg))
    x = rnd(21, (2, 5, cfg.d_model))
    got, aux_g = moe_ffn(p, x, cfg, jnp.float32, dispatch="grouped",
                         impl=impl)
    want, aux_c = moe_ffn(p, x, cfg, jnp.float32, dropless=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    for name in aux_g:
        np.testing.assert_allclose(aux_g[name], aux_c[name], rtol=1e-6)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_grouped_ffn_int8_matches_capacity(impl):
    """int8 expert weights: in-kernel post-dot dequant == capacity's
    eager pre-dot dequant (exact for scalar scales, up to fp rounding)."""
    cfg = get_smoke("mixtral_8x22b")
    p = quantize_moe_params(
        init_params(jax.random.fold_in(KEY, 4), moe_param_specs(cfg)))
    assert p["w_up"].dtype == jnp.int8 and "w_up_scale" in p
    x = rnd(22, (1, 7, cfg.d_model))
    got, _ = moe_ffn(p, x, cfg, jnp.float32, dispatch="grouped",
                     impl=impl)
    want, _ = moe_ffn(p, x, cfg, jnp.float32, dropless=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_grouped_decode_matches_prefill_packing():
    """Chunk invariance: each token's grouped output is independent of
    what else shares the dispatch (the dropless serving contract) — a
    7-token prefill equals seven 1-token decode dispatches."""
    cfg = get_smoke("kimi_k2_1t_a32b")
    p = init_params(jax.random.fold_in(KEY, 5), moe_param_specs(cfg))
    x = rnd(23, (1, 7, cfg.d_model))
    full, _ = moe_ffn(p, x, cfg, jnp.float32, dispatch="grouped",
                      impl="ref")
    for t in range(7):
        step, _ = moe_ffn(p, x[:, t:t + 1], cfg, jnp.float32,
                          dispatch="grouped", impl="ref")
        np.testing.assert_allclose(step[0, 0], full[0, t],
                                   rtol=1e-6, atol=1e-6)


def test_grouped_matmul_expert_parallel_psum(subproc):
    """shard_map EP wrapper: experts sharded over "data" == single-host,
    including int8 scales riding the expert shard."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
from repro.kernels import ops as kops, ref
mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.key(0)
m, d, f, e = 32, 16, 24, 8
gids = jnp.array([0, 1, 3, -1], jnp.int32)
x = jax.random.normal(jax.random.fold_in(key, 1), (m, d))
w = jax.random.normal(jax.random.fold_in(key, 2), (e, d, f))
scale = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (e,))) + .1
for sc in (None, scale):
    ws = w.astype(jnp.int8) if sc is not None else w
    got = kops.grouped_matmul(x, ws, gids, w_scale=sc, impl="ref",
                              mesh=mesh, expert_axis="data")
    want = ref.grouped_matmul_ref(x, ws, gids, w_scale=sc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
# non-divisible fallback: 8 experts on a 3-way axis -> replicated compute
mesh3 = jax.make_mesh((3,), ("data",))
got = kops.grouped_matmul(x, w, gids, impl="ref", mesh=mesh3,
                          expert_axis="data")
np.testing.assert_allclose(np.asarray(got),
                           np.asarray(ref.grouped_matmul_ref(x, w, gids)),
                           rtol=1e-6, atol=1e-6)
print("EP-OK")
""", devices=8)
