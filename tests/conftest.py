import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 420) -> str:
    """Run python code in a fresh process with N fake XLA devices.

    Multi-device tests must not pollute this process (jax locks the device
    count at first init), so anything needing a mesh > 1 runs here.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess
