"""Fault-injected serving: the deterministic chaos harness and every
recovery path it exercises.

Covers the tier-1 resilience contract end to end: the fault schedule is
a seed-keyed pure function (byte-identical across runs, independent of
traffic and query order), survivors of an injected schedule emit
byte-identical tokens to the fault-free run with the full feature stack
live (disaggregation + prefix cache + speculation + int8 pages),
corrupted prefix hashes are quarantined and never re-adopted, a disabled
injector leaves the engine bit-identical to one without the harness,
the scheduler's aged-priority and terminal-failure edges, SLO-aware
admission shedding, and the ``startup_bist`` kernel self-test
(``launch/serve.py --bist``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core.sdc import FaultModel, faulty_wrap
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.admission import AdmissionController, AdmissionPolicy
from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultInjector, FaultPlan, startup_bist
from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                   PrefillWorkerPool, Request)

from optional_deps import hypothesis, st

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


def _reqs(cfg, n, *, seed=1, lo=9, hi=14, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(lo, hi + 1))),
                    max_new=max_new)
            for i in range(n)]


CHAOS = FaultPlan(seed=7, worker_fail_rate=0.25, page_flip_rate=0.25,
                  transfer_drop_rate=0.2, straggler_rate=0.2)


# ----------------------------------------------------- schedule purity


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="must be in"):
        FaultPlan(page_flip_rate=1.5)
    with pytest.raises(ValueError, match="horizon"):
        FaultPlan(horizon_boundaries=0)
    with pytest.raises(ValueError, match="delays"):
        FaultPlan(straggler_extra_boundaries=0)


def test_fault_schedule_is_seed_deterministic():
    a, b = FaultInjector(CHAOS), FaultInjector(CHAOS)
    assert a.schedule_digest() == b.schedule_digest()
    assert FaultInjector(
        FaultPlan(**{**CHAOS.__dict__, "seed": 8})).schedule_digest() \
        != a.schedule_digest()


def test_fault_schedule_independent_of_query_order():
    """Queries are pure reads: interleaving kinds, repeating boundaries,
    or querying out of order never changes any answer — the property
    that makes the schedule independent of traffic and policy."""
    a, b = FaultInjector(CHAOS), FaultInjector(CHAOS)
    fwd = [(a.worker_failure(i), a.page_flip(i), a.transfer_drop(i),
            a.straggler(i)) for i in range(64)]
    for i in reversed(range(64)):  # reversed + repeated reads
        assert b.straggler(i) == fwd[i][3]
        assert b.page_flip(i) == fwd[i][1]
        assert b.page_flip(i) == fwd[i][1]
        assert b.worker_failure(i) == fwd[i][0]
        assert b.transfer_drop(i) == fwd[i][2]
    assert b.schedule_digest() == a.schedule_digest()
    # past the horizon the schedule is silent
    assert a.worker_failure(CHAOS.horizon_boundaries) is None
    assert a.straggler(-1) == 0


@hypothesis.given(seed=st.integers(min_value=0, max_value=1 << 20))
@hypothesis.settings(max_examples=10, deadline=None)
def test_fault_schedule_digest_property(seed):
    plan = FaultPlan(seed=seed, worker_fail_rate=0.3, page_flip_rate=0.1,
                     transfer_drop_rate=0.2, straggler_rate=0.4,
                     horizon_boundaries=256)
    assert FaultInjector(plan).schedule_digest() == \
        FaultInjector(plan).schedule_digest()


@hypothesis.given(rate=st.floats(min_value=0.05, max_value=0.95),
                  boundary=st.integers(min_value=0, max_value=255))
@hypothesis.settings(max_examples=10, deadline=None)
def test_fault_kinds_draw_from_independent_streams(rate, boundary):
    """Each kind's stream is keyed by crc32(kind): changing one kind's
    rate never perturbs another kind's hit pattern (the per-kind RNG
    split that keeps the schedule policy-independent)."""
    base = FaultInjector(FaultPlan(seed=3, horizon_boundaries=256,
                                   straggler_rate=0.5))
    other = FaultInjector(FaultPlan(seed=3, horizon_boundaries=256,
                                    straggler_rate=0.5,
                                    worker_fail_rate=rate,
                                    page_flip_rate=rate))
    assert base.straggler(boundary) == other.straggler(boundary)


# ------------------------------------------------ engine under faults


def _build(cfg, *, faults=None, admission=None, retry_budget=3, spec=True,
           int8=False, disagg=True):
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                       decode_cache_dtype=jnp.int8 if int8 else None)
    return ServeEngine(cfg, ctx, window=32, max_batch=2, chunk=2,
                       page_size=8, draft_k=2 if spec else 0,
                       disaggregate=disagg,
                       prefill_workers=2 if disagg else 1,
                       faults=faults, admission=admission,
                       retry_budget=retry_budget)


@pytest.mark.parametrize("int8", [False, True])
def test_survivor_token_parity_under_full_fault_schedule(qwen, int8):
    """The fatal tier-1 gate at full feature depth: disaggregated
    prefill + prefix cache + speculation (+ int8 pages) under worker
    kills, page flips, transfer drops and stragglers — every survivor's
    token stream byte-identical to the fault-free run, nonzero
    detections, and quarantined prefix hashes never re-adopted."""
    cfg, params = qwen
    base = _build(cfg, int8=int8).run(params, _reqs(cfg, 3))
    eng = _build(cfg, faults=FaultInjector(CHAOS), int8=int8)
    out = eng.run(params, _reqs(cfg, 3))
    fs = eng.fault_stats
    assert fs["fault_detections"] > 0
    assert fs["fault_worker_failures"] > 0
    assert fs["fault_page_corruptions"] > 0
    assert len(out) >= 2  # the schedule must not wipe out the batch
    for rid, toks in out.items():
        np.testing.assert_array_equal(toks, base[rid])
    # quarantine is sticky: a poisoned prefix hash leaves the index and
    # can never be re-adopted by a later admission
    assert fs["fault_pages_quarantined"] > 0
    assert eng.kv._quarantined
    assert not (eng.kv._quarantined & set(eng.kv._index))


def test_disabled_injector_is_bit_identical_to_no_harness(qwen):
    """faults=None must leave the engine byte-identical to pre-harness
    behavior, and an all-zero-rate injector must match as well (CRC
    stamping is observability, not behavior)."""
    cfg, params = qwen
    plain = _build(cfg).run(params, _reqs(cfg, 3))
    silent = _build(cfg, faults=FaultInjector(FaultPlan(seed=7)))
    out = silent.run(params, _reqs(cfg, 3))
    assert set(out) == set(plain)
    for rid in out:
        np.testing.assert_array_equal(out[rid], plain[rid])
    assert sum(silent.fault_stats.values()) == 0


def test_retry_budget_exhaustion_fails_deterministically(qwen):
    """retry_budget=0 + certain page corruption: the first detected
    fault on a request is terminal (state="failed"), the run still
    completes, and any survivors still match the fault-free tokens."""
    cfg, params = qwen
    plan = FaultPlan(seed=11, page_flip_rate=1.0)
    base = _build(cfg, disagg=False).run(params, _reqs(cfg, 3))
    eng = _build(cfg, faults=FaultInjector(plan), retry_budget=0,
                 disagg=False)
    out = eng.run(params, _reqs(cfg, 3))
    s = eng.scheduler
    assert s.stats["failures"] > 0
    assert s.stats["replays"] == 0  # budget 0: no requeues, only fails
    assert all(r.state == "failed" for r in s.failed)
    assert len(out) + len(s.failed) == 3
    for rid, toks in out.items():
        np.testing.assert_array_equal(toks, base[rid])


# ------------------------------------------------- scheduler edges


def test_aged_request_outranks_fresh_arrivals():
    sched = ContinuousBatchingScheduler(2, aged_priority_after=2)
    old = Request(rid=0, prompt=np.arange(4), max_new=2, arrival=0)
    fresh = Request(rid=1, prompt=np.arange(4), max_new=2, arrival=0)
    sched.add(old)
    sched.add(fresh)
    assert sched.next_admittable(0) is old  # FIFO ties break by rid
    old.preemptions = 1
    old.retries = 1  # preemptions + retries hits the threshold
    fresh.arrival = -1  # even an older arrival loses to an aged request
    assert sched.next_admittable(0) is old


def test_not_before_backoff_gates_admission_and_pool_routing():
    sched = ContinuousBatchingScheduler(2)
    req = Request(rid=0, prompt=np.arange(4), max_new=2, arrival=0)
    sched.add(req)
    sched.admit(req, 0)
    sched.requeue(req, not_before=6)
    assert req.retries == 1 and not req.prefill_done
    assert sched.next_admittable(5) is None
    assert sched.next_admittable(6) is req
    assert sched.stats["replays"] == 1


def test_terminal_failure_from_waiting_and_running():
    sched = ContinuousBatchingScheduler(2)
    a = Request(rid=0, prompt=np.arange(4), max_new=2)
    b = Request(rid=1, prompt=np.arange(4), max_new=2)
    sched.add(a)
    sched.add(b)
    sched.admit(a, 0)
    sched.fail(a)          # from running: slot must free up
    sched.fail(b)          # from waiting: must leave the queue
    assert a.state == b.state == "failed"
    assert not sched.running and not sched.waiting
    assert sched.stats["failures"] == 2
    assert sched.free_slots() == [0, 1]


def test_pool_failover_replaces_onto_survivor():
    pool = PrefillWorkerPool(2, span_len=8, chunk=4)
    reqs = [Request(rid=i, prompt=np.arange(12), max_new=2)
            for i in range(3)]
    for r in reqs:
        pool.place(r, clock=0)
    victim = 0 if pool.queues[0] else 1
    lost = pool.fail_worker(victim, clock=0)
    assert lost  # mid-flight prompts were re-placed
    assert not pool.queues[victim]  # dead worker drained
    assert pool.free_at[victim] == 16  # respawn: 4 boundaries * chunk 4
    assert pool.stats["worker_failures"] == 1
    assert pool.stats["failover_replacements"] == len(lost)
    # sole-worker pool: replays land on the same worker post-respawn
    solo = PrefillWorkerPool(1, span_len=8, chunk=4)
    solo.place(reqs[0], clock=0)
    assert solo.fail_worker(0, clock=0)
    assert len(solo.queues[0]) == 1


# --------------------------------------------------- admission control


def test_admission_policy_validation():
    with pytest.raises(ValueError, match="ttft_deadline_steps"):
        AdmissionPolicy(ttft_deadline_steps=0)
    with pytest.raises(ValueError, match="spec_off_queue_depth"):
        AdmissionPolicy(spec_off_queue_depth=-1)


def test_should_shed_spares_sunk_work():
    ctl = AdmissionController(AdmissionPolicy(ttft_deadline_steps=4))
    kw = dict(chunk=2, span_len=8, disaggregated=False)
    hopeless = Request(rid=0, prompt=np.arange(8), max_new=2, arrival=0)
    assert ctl.should_shed(hopeless, clock=10, **kw)
    fresh = Request(rid=1, prompt=np.arange(8), max_new=2, arrival=10)
    assert not ctl.should_shed(fresh, clock=10, **kw)
    # replayed/preempted/generating requests are never shed: their
    # accrued wait reflects the fault, not their viability
    replayed = Request(rid=2, prompt=np.arange(8), max_new=2, arrival=0)
    replayed.retries = 1
    assert not ctl.should_shed(replayed, clock=10, **kw)
    generating = Request(rid=3, prompt=np.arange(8), max_new=4, arrival=0)
    generating.generated.append(5)
    assert not ctl.should_shed(generating, clock=10, **kw)
    assert not AdmissionController().should_shed(hopeless, clock=10, **kw)


def test_engine_sheds_late_requests_and_preserves_served_tokens(qwen):
    """A TTFT deadline sheds requests that arrive into a hopeless queue;
    the ones actually served still match the no-admission run token for
    token (shedding changes batch composition, which must not change
    per-request tokens)."""
    cfg, params = qwen
    def reqs():
        out = _reqs(cfg, 4, max_new=6)
        for i, r in enumerate(out):
            r.arrival = 0 if i < 2 else 1  # latecomers behind a full batch
        return out
    base = _build(cfg, disagg=False, spec=False).run(params, reqs())
    ctl = AdmissionController(AdmissionPolicy(ttft_deadline_steps=3))
    eng = _build(cfg, disagg=False, spec=False, admission=ctl)
    out = eng.run(params, reqs())
    assert eng.fault_stats["shed_requests"] > 0
    assert eng.scheduler.shed  # state="shed", never admitted
    assert all(r.state == "shed" for r in eng.scheduler.shed)
    assert len(out) + len(eng.scheduler.shed) == 4
    for rid, toks in out.items():
        np.testing.assert_array_equal(toks, base[rid])


def test_queue_pressure_drops_speculation_token_identically(qwen):
    cfg, params = qwen
    base = _build(cfg, disagg=False).run(params, _reqs(cfg, 4))
    ctl = AdmissionController(AdmissionPolicy(spec_off_queue_depth=0))
    eng = _build(cfg, disagg=False, admission=ctl)
    out = eng.run(params, _reqs(cfg, 4))
    assert eng.fault_stats["shed_spec_chunks"] > 0
    assert eng.fault_stats["shed_requests"] == 0  # no deadline set
    assert set(out) == set(base)
    for rid in out:
        np.testing.assert_array_equal(out[rid], base[rid])


# ------------------------------------------------------- startup BIST


def test_startup_bist_passes_on_healthy_kernels():
    res = startup_bist(interpret=True)
    assert res.passed and res.matmul_report.passed and res.paged_decode_ok
    assert res.paged_decode_max_err < 5e-2


def test_startup_bist_catches_injected_kernel_faults():
    bad_mm = faulty_wrap(lambda a, b: a @ b,
                         FaultModel(rate=1.0, magnitude=0.5, seed=1))
    res = startup_bist(interpret=True, matmul_fn=bad_mm)
    assert not res.passed and not res.matmul_report.passed
    res = startup_bist(interpret=True,
                       matmul_fn=lambda a, b: a @ b,
                       decode_fn=lambda q, k, v, t, p, **kw: jnp.zeros(
                           q.shape, q.dtype))
    assert not res.passed and not res.paged_decode_ok
