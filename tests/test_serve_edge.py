"""Serving edge cases around disaggregation and telemetry: prefill-pool
construction bounds, all-slots-parked boundary accounting vs
``transfer_stats()``, the preempt-during-park lifecycle
(``prefill_done`` reset + re-prefill), and the golden SLO snapshot —
``ServeEngine.slo_summary()`` under an injected deterministic clock,
plus the pinned ``bench_serve`` arrivals-row schema."""

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.obs.trace import SpanTracer
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                   PrefillWorkerPool, Request)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                       / "benchmarks"))
import bench_serve  # noqa: E402  (ARRIVALS_SLO_ROWS schema pin)

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


def _reqs(cfg, n, *, seed=1, lo=8, hi=14, max_new=8):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(lo, hi + 1))),
                    max_new=max_new)
            for i in range(n)]


# ------------------------------------------------ prefill pool bounds


def test_prefill_pool_rejects_zero_workers():
    with pytest.raises(ValueError, match="n_workers must be >= 1"):
        PrefillWorkerPool(0, span_len=16, chunk=4)
    cfg = get_smoke("qwen2_0_5b")
    with pytest.raises(ValueError, match="prefill_workers must be >= 1"):
        ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4,
                    page_size=8, disaggregate=True, prefill_workers=0)


def test_prefill_pool_least_loaded_placement_and_fifo():
    pool = PrefillWorkerPool(2, span_len=8, chunk=4)
    reqs = [Request(rid=i, prompt=np.arange(12), max_new=4)
            for i in range(3)]
    # 12 tokens / span 8 => 2 spans * chunk 4 = 8 boundaries each
    assert pool.place(reqs[0], clock=0) == 8
    assert pool.place(reqs[1], clock=0) == 8   # second worker, parallel
    assert pool.place(reqs[2], clock=0) == 16  # queued behind one of them
    assert sorted(pool.depths()) == [1, 2]
    assert pool.pop_ready(7) == []
    ready = pool.pop_ready(8)
    assert {r.rid for r in ready} == {0, 1}
    assert all(r.prefill_done and r.state == "waiting" for r in ready)
    assert pool.pending()
    assert [r.rid for r in pool.pop_ready(16)] == [2]
    assert not pool.pending()


# ------------------------------------- all-slots-parked accounting


def test_all_slots_parked_stall_accounting(qwen):
    """With a single decode slot, every boundary spent waiting on a page
    transfer has ALL running slots parked, so the decode-idle count must
    equal the transfer-stall count exactly — and the run must still be
    token-identical to the co-located engine."""
    cfg, params = qwen
    co = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                     page_size=8)
    want = co.run(params, _reqs(cfg, 3))
    dis = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                      page_size=8, disaggregate=True, transfer_link="dcn")
    got = dis.run(params, _reqs(cfg, 3))
    for i in range(3):
        np.testing.assert_array_equal(want[i], got[i])
    ts = dis.transfer_stats()
    assert ts["transfers"] == 3
    assert ts["transfer_stall_boundaries"] >= 1
    assert ts["decode_idle_boundaries"] == ts["transfer_stall_boundaries"]
    # parked != running: a frozen slot never counts as decode occupancy
    assert ts["decode_depth_peak"] >= 1


# ------------------------------------------- preempt during park


def test_scheduler_preempt_resets_prefill_done():
    s = ContinuousBatchingScheduler(max_slots=2)
    req = Request(rid=0, prompt=np.arange(8), max_new=4)
    req.prefill_done = True  # as set by PrefillWorkerPool.pop_ready
    s.add(req)
    s.admit(req, slot=0)
    s.preempt(req)
    assert req.prefill_done is False  # pages dropped: must re-prefill
    assert req.state == "waiting" and req.slot == -1
    assert req.preemptions == 1
    assert req in s.waiting


def test_preempt_during_park_re_prefills_and_completes(qwen):
    """Page pressure that evicts requests in a disaggregated engine: the
    victim (possibly mid-transfer) loses its pages, is re-placed on the
    prefill pool (pool placements exceed the request count), and still
    finishes with the same greedy tokens as a solo run."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=64, max_batch=3, chunk=4,
                      page_size=8, num_pages=12, disaggregate=True,
                      prefill_workers=2)
    reqs = _reqs(cfg, 5, max_new=14)
    out = eng.run(params, reqs)
    stats = eng.scheduler.stats
    assert stats["preemptions"] >= 1, "pool sized to force eviction"
    assert stats["completions"] == 5
    assert eng.prefill_pool.stats["placed"] >= 5 + stats["preemptions"]
    victim = next(r for r in eng.scheduler.finished if r.preemptions)
    solo = ServeEngine(cfg, CTX, window=64, max_batch=1, chunk=4,
                       page_size=8)
    want = solo.run(params, [Request(rid=0, prompt=victim.prompt,
                                     max_new=14)])[0]
    np.testing.assert_array_equal(out[victim.rid], want)


# ---------------------------------------------- golden SLO snapshot


class _FakeClock:
    """Deterministic monotonic clock: one second per observation."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


_SLO_KEYS = ("requests", "ttft_p50_s", "ttft_p95_s", "tpot_p50_s",
             "tpot_p95_s", "queue_wait_p50_steps", "prefill_time_s",
             "decode_time_s", "prefill_tok_s", "decode_tok_s")


def _golden_run(cfg, params):
    eng = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4,
                      page_size=8, tracer=SpanTracer(clock=_FakeClock()))
    eng.run(params, _reqs(cfg, 4, lo=8, hi=12, max_new=8))
    return eng.slo_summary()


# The snapshot under the unit-step clock: every wall-derived metric is
# a deterministic count of the engine's observation points (p95 values
# interpolate inside a histogram bucket). A change here means the
# engine moved a measurement point — update deliberately.
_GOLDEN_SLO = {
    "requests": 4.0,
    "ttft_p50_s": 10.0,
    "ttft_p95_s": 26.2,
    "tpot_p50_s": 4.0 / 7.0,
    "tpot_p95_s": 4.0 / 7.0,
    "queue_wait_p50_steps": 0.0,
    "prefill_time_s": 8.0,
    "decode_time_s": 4.0,
    "prefill_tok_s": 5.25,
    "decode_tok_s": 8.0,
}


def test_slo_summary_golden_snapshot(qwen):
    """Under an injected unit-step clock the SLO summary is an exact,
    reproducible snapshot: the schema, the measurement points, and the
    byte-identical double run are all pinned."""
    cfg, params = qwen
    slo = _golden_run(cfg, params)
    assert tuple(slo) == _SLO_KEYS
    assert slo == pytest.approx(_GOLDEN_SLO)
    again = _golden_run(cfg, params)
    assert json.dumps(slo, sort_keys=True) == \
        json.dumps(again, sort_keys=True)


def test_bench_serve_arrivals_rows_pinned(qwen):
    """The bench_serve section-2 row schema is a module constant; every
    row maps to a real slo_summary key, and the pinned tuple is exactly
    what the golden snapshot (and run.py --json consumers) rely on."""
    assert bench_serve.ARRIVALS_SLO_ROWS == (
        ("serve/ttft_p50_s", "ttft_p50_s"),
        ("serve/ttft_p95_s", "ttft_p95_s"),
        ("serve/tpot_p50_s", "tpot_p50_s"),
        ("serve/tpot_p95_s", "tpot_p95_s"),
        ("serve/queue_wait_p50_steps", "queue_wait_p50_steps"),
        ("serve/prefill_time_s", "prefill_time_s"),
        ("serve/decode_time_s", "decode_time_s"),
    )
    cfg, params = qwen
    slo = _golden_run(cfg, params)
    for row, key in bench_serve.ARRIVALS_SLO_ROWS:
        assert row.startswith("serve/")
        assert key in slo
