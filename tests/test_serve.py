"""Serving subsystem: continuous-batching parity (with eviction), paged
KV vs dense correctness, int8 page quantization, EOS handling, host-sync
regression, paged attention kernel, and scheduler invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.kernels import ops
from repro.models import api
from repro.models.blocks import ModelContext, paged_quantize
from repro.models.params import init_params
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


def prompts(cfg, n, lo, hi, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(lo, hi + 1)))
            for _ in range(n)]


# ------------------------------------------------- continuous batching


def test_continuous_batching_with_eviction_matches_solo(qwen):
    """5 requests through 3 slots and a page pool too small to hold them
    all: admissions, completions and at least one preemption — every
    request's greedy output must equal its solo run."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=64, max_batch=3, chunk=4,
                      page_size=8, num_pages=12)
    ps = prompts(cfg, 5, 8, 14)
    reqs = [Request(rid=i, prompt=p, max_new=14) for i, p in enumerate(ps)]
    out = eng.run(params, reqs)
    assert eng.scheduler.stats["preemptions"] >= 1, \
        "pool sized to force eviction"
    assert eng.scheduler.stats["completions"] == 5
    solo = ServeEngine(cfg, CTX, window=64, max_batch=1, chunk=4,
                      page_size=8)
    for i, p in enumerate(ps):
        want = solo.run(params, [Request(rid=0, prompt=p, max_new=14)])[0]
        np.testing.assert_array_equal(out[i], want)


def test_staggered_arrivals_mixed_lengths(qwen):
    """Admission mid-decode: slots hold different positions per request."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                      page_size=8)
    reqs = [Request(rid=0, prompt=prompts(cfg, 1, 6, 6)[0], max_new=10,
                    arrival=0),
            Request(rid=1, prompt=prompts(cfg, 1, 11, 11, seed=2)[0],
                    max_new=6, arrival=4),
            Request(rid=2, prompt=prompts(cfg, 1, 4, 4, seed=3)[0],
                    max_new=8, arrival=9)]
    out = eng.run(params, reqs)
    solo = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                      page_size=8)
    for r in reqs:
        want = solo.run(params, [Request(rid=0, prompt=r.prompt,
                                         max_new=r.max_new)])[0]
        np.testing.assert_array_equal(out[r.rid], want)


def test_generate_wrapper_matches_pertoken_loop(qwen):
    """The legacy generate() API rides the new engine bit-identically
    (greedy) against the pre-rebuild per-token loop."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=48, max_batch=3, chunk=5)
    rng = np.random.default_rng(4)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (3, 12)), jnp.int32)}
    ref = eng.generate_pertoken(params, batch, max_new=9)
    out = eng.generate(params, batch, max_new=9)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_dense_families_parity():
    """Attention-free (rwkv) and hybrid (jamba) ride the dense-slot
    backend; outputs must match the per-token loop."""
    for arch in ("rwkv6_1_6b", "jamba_v01_52b"):
        cfg = get_smoke(arch)
        params = init_params(jax.random.key(0), api.model_specs(cfg))
        eng = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4)
        assert not eng.paged
        rng = np.random.default_rng(5)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, 7)), jnp.int32)}
        ref = eng.generate_pertoken(params, batch, max_new=6)
        out = eng.generate(params, batch, max_new=6)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_eos_terminates_request_early(qwen):
    cfg, params = qwen
    base = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4)
    p = prompts(cfg, 1, 10, 10, seed=6)[0]
    full = base.run(params, [Request(rid=0, prompt=p, max_new=12)])[0]
    assert len(full) == 12
    eos = int(full[4])  # greedy will reproduce this token at step 4
    eng = ServeEngine(cfg, CTX, window=48, max_batch=1, chunk=4,
                      eos_id=eos)
    out = eng.run(params, [Request(rid=0, prompt=p, max_new=12)])[0]
    assert len(out) < 12
    assert out[-1] == eos
    np.testing.assert_array_equal(out, full[:len(out)])


# --------------------------------------------------------- paged cache


def test_paged_matches_dense_backend(qwen):
    """Same requests, paged pool vs dense ring slots: identical greedy
    tokens (the paged layout is a pure memory-layout change)."""
    cfg, params = qwen
    rng = np.random.default_rng(7)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (3, 10)), jnp.int32)}
    paged = ServeEngine(cfg, CTX, window=40, max_batch=3, chunk=4,
                        page_size=8, paged=True)
    dense = ServeEngine(cfg, CTX, window=40, max_batch=3, chunk=4,
                        paged=False)
    po = paged.generate(params, batch, max_new=10)
    do = dense.generate(params, batch, max_new=10)
    np.testing.assert_array_equal(np.asarray(po), np.asarray(do))


def test_paged_int8_kv_close_to_fp32(qwen):
    """int8 page quantization: logits stay close; greedy tokens agree on
    a short horizon at smoke scale."""
    cfg, params = qwen
    ctx8 = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        decode_cache_dtype=jnp.int8)
    rng = np.random.default_rng(8)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)}
    o8 = ServeEngine(cfg, ctx8, window=40, max_batch=2, chunk=4,
                     page_size=8).generate(params, batch, max_new=8)
    of = ServeEngine(cfg, CTX, window=40, max_batch=2, chunk=4,
                     page_size=8).generate(params, batch, max_new=8)
    agreement = float(np.mean(np.asarray(o8) == np.asarray(of)))
    assert agreement >= 0.75, agreement


def test_paged_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(0), (4, 16, 2, 8)) * 3.0
    q, scale = paged_quantize(x, jnp.int8)
    assert q.dtype == jnp.int8 and scale.shape == (4, 16, 2)
    back = q.astype(jnp.float32) * scale[..., None]
    err = np.max(np.abs(np.asarray(back - x)))
    bound = float(np.max(np.abs(np.asarray(x)))) / 127.0
    assert err <= bound + 1e-6


def test_paged_attention_kernel_matches_ref():
    key = jax.random.key(0)
    b, h, kv, d, p, m, n = 3, 8, 2, 32, 8, 4, 16
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, h, d))
    kp = jax.random.normal(jax.random.fold_in(key, 2), (n, p, kv, d))
    vp = jax.random.normal(jax.random.fold_in(key, 3), (n, p, kv, d))
    table = jnp.array([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]],
                      jnp.int32)
    pos = jnp.array([19, 9, 31], jnp.int32)
    for window in (None, 7):
        out = ops.paged_decode_attention(q, kp, vp, table, pos,
                                         impl="interpret", window=window)
        want = ops.paged_decode_attention(q, kp, vp, table, pos,
                                          impl="ref", window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_decode_attention_kernel_per_request_pos():
    """Regression: the dense decode kernel must honor per-request pos
    (continuous batching), not broadcast pos[0]."""
    key = jax.random.key(1)
    b, h, kv, d, w = 3, 4, 2, 32, 64
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, h, d))
    kc = jax.random.normal(jax.random.fold_in(key, 2), (b, w, kv, d))
    vc = jax.random.normal(jax.random.fold_in(key, 3), (b, w, kv, d))
    pos = jnp.array([5, 33, 64], jnp.int32)
    out = ops.decode_attention(q, kc, vc, pos, impl="interpret",
                               block_k=32)
    want = ops.decode_attention(q, kc, vc, pos, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------- engine decode via Pallas kernel


def test_engine_decode_through_pallas_paged_kernel(qwen):
    """attn_impl='pallas_interpret' must route the engine's paged decode
    through the scalar-prefetch Pallas kernel (no gather oracle) and
    reproduce the oracle's greedy tokens exactly."""
    cfg, params = qwen
    ctxp = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        attn_impl="pallas_interpret")
    rng = np.random.default_rng(11)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)}
    kern = ServeEngine(cfg, ctxp, window=40, max_batch=2, chunk=4,
                       page_size=8)
    orac = ServeEngine(cfg, CTX, window=40, max_batch=2, chunk=4,
                       page_size=8)
    assert kern.paged and orac.paged
    ok = kern.generate(params, batch, max_new=8)
    oo = orac.generate(params, batch, max_new=8)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oo))


def test_engine_pallas_int8_pages_stream_through_kernel(qwen):
    """int8 pages stream natively through the scalar-prefetch kernel
    (in-VMEM dequant via the scale pages) and must reproduce the jnp
    gather-dequant oracle's greedy tokens exactly."""
    cfg, params = qwen
    ctx8p = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                         decode_cache_dtype=jnp.int8,
                         attn_impl="pallas_interpret")
    ctx8 = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                        decode_cache_dtype=jnp.int8)
    rng = np.random.default_rng(12)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)}
    a = ServeEngine(cfg, ctx8p, window=40, max_batch=2, chunk=4,
                    page_size=8).generate(params, batch, max_new=6)
    b = ServeEngine(cfg, ctx8, window=40, max_batch=2, chunk=4,
                    page_size=8).generate(params, batch, max_new=6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------- SWA page freeing (paged KV)


def test_sliding_window_frees_pages_behind_window():
    """SWA archs (mixtral) must return pages behind the window to the
    pool mid-decode while keeping per-token parity with the dense ring
    oracle (the mask already bounded attention; now memory too)."""
    cfg = get_smoke("mixtral_8x22b")
    assert cfg.sliding_window is not None
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    eng = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                      page_size=4)
    assert eng.paged
    rng = np.random.default_rng(13)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)}
    out = eng.generate(params, batch, max_new=30)
    assert eng.counters["pages_trimmed"] > 0
    ref = eng.generate_pertoken(params, batch, max_new=30)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_paged_trim_grow_bookkeeping():
    """Unit-level: trim releases whole pages behind the floor, keeps the
    frontier monotonic, and release() reclaims everything."""
    from repro.serve.kv_cache import PagedKVCache
    cfg = get_smoke("qwen2_0_5b")
    kv = PagedKVCache(cfg, CTX, num_pages=16, page_size=4, max_batch=2,
                      max_pages_per_seq=8)
    assert kv.grow(0, 20)  # 5 pages
    n_free = kv.free_page_count()
    assert kv.trim(0, 9) == 2  # pages for tokens 0..7 freed
    assert kv.free_page_count() == n_free + 2
    assert kv.slot_pages(0) == [int(p) for p in kv._table[0] if p != 0]
    # grow continues from the frontier, never refilling trimmed history
    assert kv.grow(0, 28)  # 7 pages total frontier
    assert int(kv._frontier[0]) == 7
    assert all(int(kv._table[0][i]) == 0 for i in range(2))
    kv.release(0)
    assert kv.free_page_count() == 15  # all but trash page 0
    assert int(kv._frontier[0]) == 0


# ------------------------------------- state-family prefill bucketing


def test_state_family_prefill_buckets_to_pow2():
    """rwkv6 prompts of different lengths share one power-of-two prefill
    compilation and match the per-token oracle exactly."""
    cfg = get_smoke("rwkv6_1_6b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    eng = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=4)
    assert eng.bucket_prefill
    rng = np.random.default_rng(14)
    prompts_ = [rng.integers(0, cfg.vocab_size, n) for n in (9, 11, 13, 15)]
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts_)]
    out = eng.run(params, reqs)
    assert eng.prefill_bucket_sizes == {16}
    for i, p in enumerate(prompts_):
        ref = eng.generate_pertoken(
            params, {"tokens": jnp.asarray(p[None, :])}, max_new=6)
        np.testing.assert_array_equal(out[i], np.asarray(ref)[0])


def test_attention_stacks_do_not_bucket(qwen):
    cfg, _ = qwen
    eng = ServeEngine(cfg, CTX, window=48, max_batch=2, chunk=4,
                      paged=False)
    assert not eng.bucket_prefill  # front padding would shift positions


def test_mamba_front_pad_mask_keeps_state_exact():
    """Direct check of the masked-conv property: a front-padded mamba
    prefill reproduces the unpadded output and final state."""
    from repro.models.mamba import mamba_forward, mamba_param_specs
    cfg = get_smoke("jamba_v01_52b")
    specs = mamba_param_specs(cfg)
    params = init_params(jax.random.key(1), specs)
    # nonzero conv bias is exactly the term the mask neutralizes
    params["conv_b"] = jax.random.normal(
        jax.random.key(2), params["conv_b"].shape) * 0.3
    x = jax.random.normal(jax.random.key(3), (2, 6, cfg.d_model),
                          jnp.float32)
    out, (conv, ssm) = mamba_forward(params, x, cfg, jnp.float32,
                                     chunk=2, return_state=True)
    pad = 2
    xp = jnp.concatenate([jnp.zeros((2, pad, cfg.d_model)), x], axis=1)
    mask = (jnp.arange(6 + pad)[None, :] >= pad).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (2, 6 + pad))
    outp, (convp, ssmp) = mamba_forward(params, xp, cfg, jnp.float32,
                                        chunk=2, return_state=True,
                                        seq_mask=mask)
    np.testing.assert_allclose(np.asarray(outp[:, pad:]), np.asarray(out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(convp), np.asarray(conv),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ssmp), np.asarray(ssm),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ host-sync count


def test_decode_loop_host_sync_regression(qwen):
    """Generating N tokens with chunk C must sync the host exactly
    ceil(N/C) times — the device-resident loop contract. The per-token
    loop pays one jit dispatch per token instead."""
    cfg, params = qwen
    eng = ServeEngine(cfg, CTX, window=48, max_batch=4, chunk=8)
    rng = np.random.default_rng(9)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 12)), jnp.int32)}
    eng.generate(params, batch, max_new=24)
    assert eng.counters["chunks"] == 3  # ceil(24/8)
    assert eng.counters["host_syncs"] == 3
    assert eng.counters["prefills"] == 4
    eng.generate_pertoken(params, batch, max_new=24)
    assert eng.counters["pertoken_steps"] == 24


# ----------------------------------------------------------- scheduler


def test_scheduler_admission_order_and_slot_reuse():
    s = ContinuousBatchingScheduler(max_slots=2)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 10, 4), max_new=2,
                    arrival=a) for i, a in enumerate([5, 0, 0])]
    for r in reqs:
        s.add(r)
    # arrival order wins over rid submission order
    assert s.next_admittable(0).rid == 1
    s.admit(reqs[1], 0)
    s.admit(reqs[2], 1)
    assert s.free_slots() == []
    assert s.next_admittable(10).rid == 0
    s.complete(0)
    assert s.free_slots() == [0]
    s.admit(reqs[0], 0)
    assert s.running[0].rid == 0


def test_scheduler_preempts_youngest():
    s = ContinuousBatchingScheduler(max_slots=3)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 10, 4), max_new=4,
                    arrival=a) for i, a in enumerate([0, 3, 7])]
    for i, r in enumerate(reqs):
        s.add(r)
        s.admit(r, i)
    victim = s.preempt_victim()
    assert victim.rid == 2  # latest arrival
    s.preempt(victim)
    assert victim.state == "waiting" and victim.preemptions == 1
    assert s.waiting[0] is victim  # back of the arrival-ordered queue
    assert len(s.running) == 2


def test_request_resume_prompt_folds_generated():
    req = Request(rid=0, prompt=np.arange(5), max_new=10)
    req.generated = [7, 8]
    np.testing.assert_array_equal(req.resume_prompt(),
                                  [0, 1, 2, 3, 4, 7, 8])
    assert req.remaining == 8
