"""Unified telemetry: metrics registry semantics, span tracer / Chrome
trace validation, steptrace round-trips, measured step-time models, and
the engine/trainer integration invariants (telemetry must not change
tokens; one merged timeline must validate with serve+train+fleet
categories)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.fleet.perf import MeasuredStepTimeModel, StepTimeModel, \
    job_spec_from_trace
from repro.fleet.sim import FleetConfig, FleetSimulator
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.obs.metrics import (CATALOG, CounterDict, Histogram,
                               MetricsRegistry, NULL_METRIC)
from repro.obs.steptrace import StepTrace
from repro.obs.trace import (SpanTracer, merge_chrome_traces,
                             validate_chrome_trace)
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request

jax.config.update("jax_default_matmul_precision", "highest")

CTX = ModelContext(compute_dtype=jnp.float32, q_chunk=64, mamba_chunk=8,
                   rwkv_chunk=4)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    return cfg, params


class FakeClock:
    """Deterministic injectable clock: each call advances by ``dt``."""

    def __init__(self, dt=1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self):
        self.t += self.dt
        return self.t


# ------------------------------------------------------------- metrics


def test_histogram_bucket_edges():
    h = Histogram("h", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 99.0):  # 1.0 lands in its own bucket
        h.observe(v)
    assert h.counts == [2, 1, 1, 1]  # <=1, <=2, <=4, overflow
    assert h.count == 5
    assert h.min == 0.5 and h.max == 99.0
    d = h.to_dict()
    assert d["count"] == 5 and d["edges"] == [1.0, 2.0, 4.0]
    assert 0.5 <= d["p50"] <= 2.0  # interpolated, clamped to observed
    assert d["p99"] <= 99.0


def test_histogram_rejects_unsorted_edges():
    with pytest.raises(ValueError):
        Histogram("bad", edges=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("dup", edges=(1.0, 1.0))


def test_empty_histogram_is_zero():
    h = Histogram("h")
    assert h.mean == 0.0 and h.quantile(0.5) == 0.0
    assert h.to_dict()["min"] == 0.0


def test_disabled_registry_is_null_and_allocates_nothing(tmp_path):
    reg = MetricsRegistry(enabled=False)
    assert reg.counter("a") is NULL_METRIC
    assert reg.gauge("b") is NULL_METRIC
    assert reg.histogram("c") is NULL_METRIC
    reg.counter("a").inc()
    reg.histogram("c").observe(1.0)
    reg.compile_event("f")
    assert reg._metrics == {}  # nothing ever allocated
    assert reg.snapshot() == {}
    out = tmp_path / "m.jsonl"
    reg.to_jsonl(str(out))
    assert not out.exists()  # disabled -> no file touched


def test_registry_snapshot_and_jsonl(tmp_path):
    clk = FakeClock()
    reg = MetricsRegistry(clock=clk)
    reg.counter("serve_chunks").inc(3)
    reg.histogram("serve_ttft_s").observe(0.02)
    snap = reg.snapshot()
    assert snap["serve_chunks"] == 3
    assert snap["serve_ttft_s"]["count"] == 1
    out = tmp_path / "m.jsonl"
    reg.to_jsonl(str(out))
    reg.to_jsonl(str(out))  # appends
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["metrics"]["serve_chunks"] == 3
    assert lines[1]["t"] > lines[0]["t"]


def test_counterdict_facade_routes_into_registry():
    reg = MetricsRegistry()
    cd = CounterDict(reg, ("chunks", "host_syncs"), prefix="serve_")
    cd["chunks"] += 2
    cd["host_syncs"] = 7
    cd["host_syncs"] = 0  # bench-style reset
    assert cd["chunks"] == 2
    assert reg.counter("serve_chunks").value == 2
    assert dict(cd) == {"chunks": 2, "host_syncs": 0}
    with pytest.raises(KeyError):
        cd["nope"]
    with pytest.raises(TypeError):
        del cd["chunks"]


def test_compile_event_counts_compiles():
    reg = MetricsRegistry()
    reg.compile_event("serve_span_prefill")
    reg.compile_event("serve_span_prefill")
    assert reg.counter("serve_span_prefill_compiles").value == 2


def test_catalog_names_have_role_prefixes():
    assert all(n.startswith(("serve_", "train_")) for n in CATALOG)


# --------------------------------------------------------------- trace


def test_span_nesting_and_ordering_with_fake_clock():
    tr = SpanTracer(clock=FakeClock())
    pid = tr.process("serve")
    tr.thread(pid, 0, "slot0")
    tr.begin("req:0", pid=pid, tid=0, cat="serve")
    tr.begin("prefill", pid=pid, tid=0, cat="serve")
    tr.end(pid=pid, tid=0)  # closes prefill
    tr.end(pid=pid, tid=0)  # closes req:0
    names = [e["name"] for e in tr.events if e["ph"] == "E"]
    assert names == ["prefill", "req:0"]  # LIFO close order
    doc = tr.chrome_trace()
    assert validate_chrome_trace(doc, require_cats=["serve"]) == []
    ts = [e["ts"] for e in doc["traceEvents"] if e["ph"] in "BE"]
    assert ts == sorted(ts) and ts[0] == 0.0  # rebased to t=0


def test_validator_flags_unbalanced_and_regressed():
    tr = SpanTracer(clock=FakeClock())
    tr.begin("open", pid=0, tid=0)
    probs = validate_chrome_trace(tr.chrome_trace())
    assert any("unclosed" in p for p in probs)
    tr2 = SpanTracer()
    tr2.emit({"ph": "B", "pid": 0, "tid": 0, "name": "a", "ts": 10.0})
    tr2.emit({"ph": "E", "pid": 0, "tid": 0, "name": "a", "ts": 5.0})
    probs = validate_chrome_trace(tr2.chrome_trace())
    assert any("regressed" in p or "ends before" in p for p in probs)
    tr3 = SpanTracer()
    tr3.emit({"ph": "X", "pid": 0, "tid": 0, "name": "x", "ts": 0.0,
              "dur": -1.0})
    assert any("non-negative" in p
               for p in validate_chrome_trace(tr3.chrome_trace()))
    tr4 = SpanTracer()
    tr4.emit({"ph": "E", "pid": 0, "tid": 0, "name": "a", "ts": 0.0})
    assert any("without open B" in p
               for p in validate_chrome_trace(tr4.chrome_trace()))


def test_disabled_tracer_records_nothing():
    tr = SpanTracer(enabled=False)
    assert tr.process("p") == 0
    tr.begin("a")
    tr.end()
    tr.complete("b", 1.0)
    tr.instant("c")
    tr.counter("d", {"v": 1.0})
    assert tr.events == []


def test_chrome_trace_roundtrip_and_merge(tmp_path):
    a = SpanTracer(clock=FakeClock())
    pa = a.process("serve")
    with a.span("req", pid=pa, cat="serve"):
        a.complete("prefill", 0.5, pid=pa, cat="serve")
    b = SpanTracer(clock=FakeClock())
    pb = b.process("train")
    b.complete("step", 0.1, pid=pb, cat="train")
    path = tmp_path / "t.json"
    a.write(str(path))
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    assert validate_chrome_trace(doc, require_cats=["serve"]) == []
    merged = merge_chrome_traces([doc, b.chrome_trace()])
    assert validate_chrome_trace(
        merged, require_cats=["serve", "train"]) == []
    # pid remap keeps the sources on disjoint process rows
    pids_a = {e["pid"] for e in doc["traceEvents"]}
    pids_b = {e["pid"] for e in merged["traceEvents"]
              if e.get("cat") == "train" or
              (e["ph"] == "M" and e["args"]["name"] == "train")}
    assert pids_a.isdisjoint(pids_b)


# ----------------------------------------------------------- steptrace


def test_steptrace_roundtrip(tmp_path):
    st = StepTrace(source="serve", meta={"arch": "qwen"})
    st.record("prefill", 0.2, tokens=12, batch=1)
    st.record("decode", 0.1, batch=2, steps=4)
    with pytest.raises(ValueError):
        st.record("banana", 1.0)
    path = tmp_path / "st.json"
    st.write(str(path))
    back = StepTrace.read(str(path))
    assert back.source == "serve" and back.meta == {"arch": "qwen"}
    assert len(back) == 2
    assert back.events[0].features == {"tokens": 12.0, "batch": 1.0}
    assert back.durations(("decode",)) == [0.1]
    with pytest.raises(ValueError):
        StepTrace.from_dict({"schema": "nope"})


def test_from_trace_replay_equals_recorded():
    st = StepTrace(source="train")
    for d in (0.5, 0.3, 0.4):
        st.record("step", d)
    st.record("replay", 9.0)  # rework: excluded from effective kinds
    model = StepTimeModel.from_trace(st, cubes_ref=2)
    assert isinstance(model, MeasuredStepTimeModel)
    assert model.replay() == (0.5, 0.3, 0.4)
    assert model.mean_step_s == pytest.approx(0.4)
    assert model(2) == pytest.approx(0.4)  # at the reference size
    assert model(4) == pytest.approx(0.2)  # ideal-linear rescale
    with pytest.raises(ValueError):
        StepTimeModel.from_trace(StepTrace())  # no measured durations


def test_from_trace_drives_fleet_sim():
    st = StepTrace(source="serve")
    for d in (0.02, 0.04, 0.03):
        st.record("decode", d, batch=2)
    spec = job_spec_from_trace("measured", st, chips=64, total_steps=10,
                               checkpoint_every_steps=5)
    assert spec.step_time_s == pytest.approx(0.03)
    sim = FleetSimulator(FleetConfig(tpu="ironwood", total_cubes=2,
                                     host_mtbf_hours=None), [spec])
    sim.run(60.0)
    job = sim.jobs["measured"]
    assert job.state == "done"
    assert job.ledger.goodput == pytest.approx(1.0)


# ------------------------------------------------- engine integration


def _run_engine(cfg, params, ps, **kw):
    eng = ServeEngine(cfg, CTX, window=32, max_batch=2, chunk=2,
                      page_size=8, **kw)
    out = eng.run(params, [Request(rid=i, prompt=p, max_new=4)
                           for i, p in enumerate(ps)])
    return eng, out


def test_engine_telemetry_does_not_change_tokens(qwen):
    """Default engine vs fully-instrumented vs fully-disabled telemetry:
    token-identical outputs (all instrumentation is host-side)."""
    cfg, params = qwen
    rng = np.random.default_rng(0)
    ps = [rng.integers(0, cfg.vocab_size, n) for n in (9, 13, 6)]
    _, base = _run_engine(cfg, params, ps)
    on_eng, on = _run_engine(cfg, params, ps, metrics=MetricsRegistry(),
                             tracer=SpanTracer())
    off_eng, off = _run_engine(cfg, params, ps,
                               metrics=MetricsRegistry(enabled=False),
                               tracer=SpanTracer(enabled=False))
    for i in range(len(ps)):
        np.testing.assert_array_equal(base[i], on[i])
        np.testing.assert_array_equal(base[i], off[i])
    # the instrumented run populated SLO metrics and a valid trace
    snap = on_eng.metrics.snapshot()
    assert snap["serve_requests_admitted"] == len(ps)
    assert snap["serve_requests_finished"] == len(ps)
    assert snap["serve_ttft_s"]["count"] == len(ps)
    assert snap["serve_tpot_s"]["count"] == len(ps)  # max_new>1 for all
    assert snap["serve_generated_tokens"] == sum(
        4 for _ in ps)
    assert validate_chrome_trace(on_eng.tracer.chrome_trace(),
                                 require_cats=["serve"]) == []
    slo = on_eng.slo_summary()
    assert slo["requests"] == len(ps)
    assert slo["ttft_p95_s"] >= slo["ttft_p50_s"] >= 0.0
    assert slo["prefill_time_s"] > 0.0 and slo["decode_time_s"] > 0.0
    # the disabled run allocated no metric state at all
    assert off_eng.metrics.snapshot() == {}
    assert off_eng.tracer.events == []
    # measured steptrace carries both roles' chunk kinds
    kinds = {e.kind for e in on_eng.steptrace.events}
    assert kinds == {"prefill", "decode"}


def test_trainer_telemetry_matches_replay_summary(tmp_path):
    from repro.launch.train import build_trainer
    from repro.resilience.driver import StragglerPolicy

    tracer = SpanTracer()
    trainer, state = build_trainer(
        get_smoke("qwen2_0_5b"), batch=2, seq=16,
        ckpt_dir=str(tmp_path / "ckpt"), checkpoint_every=4,
        failures={5: 0}, tracer=tracer)
    trainer.straggler = StragglerPolicy(threshold=float("inf"))
    _, ledger, losses = trainer.run(state, 8)
    rs = trainer.replay_summary()
    snap = trainer.metrics.snapshot()
    assert snap["train_steps"] == rs["effective_steps"] == len(losses)
    assert snap["train_replayed_steps"] == rs["replayed_steps"]
    assert snap["train_failures"] == 1
    assert snap["train_restores"] == 1
    assert snap["train_ckpt_saves"] >= 2  # bootstrap + periodic
    assert snap["train_step_s"]["count"] == len(losses)
    assert validate_chrome_trace(tracer.chrome_trace(),
                                 require_cats=["train"]) == []
    names = {e["name"] for e in tracer.events if e.get("ph") == "X"}
    assert {"step", "ckpt", "detect", "restore", "replay"} <= names
    st = trainer.steptrace()
    assert st.durations(("replay",)) and st.durations(("step",))
    assert len(st) == len(trainer.records)


def test_one_timeline_serve_train_fleet(qwen):
    """The acceptance shape: serve request spans, trainer-style step
    spans, and fleet-sim events merged into one validating document."""
    cfg, params = qwen
    rng = np.random.default_rng(1)
    ps = [rng.integers(0, cfg.vocab_size, 7)]
    eng, _ = _run_engine(cfg, params, ps, tracer=SpanTracer())

    shared = SpanTracer(clock=FakeClock(0.01))
    tp = shared.process("train")
    shared.complete("step", 0.1, pid=tp, cat="train")
    st = StepTrace(source="serve")
    st.record("decode", 0.05)
    spec = job_spec_from_trace("measured", st, chips=64, total_steps=4,
                               checkpoint_every_steps=2)
    sim = FleetSimulator(FleetConfig(tpu="ironwood", total_cubes=2,
                                     host_mtbf_hours=None), [spec],
                         tracer=shared)
    sim.run(10.0)
    assert sim.jobs["measured"].state == "done"
    merged = merge_chrome_traces([eng.tracer.chrome_trace(),
                                  shared.chrome_trace()])
    assert validate_chrome_trace(
        merged, require_cats=["serve", "train", "fleet"]) == []
