"""Figure 5: relative peak performance per TDP Watt across generations."""

from repro.core import cci, hwspec

PAPER_FIG5 = {"tpu_v2": 1.0, "tpu_v3": 1.8, "tpu_v4": 4.9,
              "tpu_v5p": 5.2, "ironwood": 29.3}


def run(emit) -> None:
    derived = cci.perf_per_watt_relative()
    for name, val in derived.items():
        claim = PAPER_FIG5[name]
        ok = abs(val - claim) / claim < 0.05
        emit(f"fig5/perf_per_watt_{name}", val,
             f"paper={claim} {'OK' if ok else 'MISMATCH'}")
    # paper: "6X for Ironwood from TPU v5p"
    ratio = derived["ironwood"] / derived["tpu_v5p"]
    emit("fig5/ironwood_vs_v5p", ratio,
         f"paper=~6x {'OK' if 5.0 < ratio < 7.0 else 'MISMATCH'}")
