"""§Resilience goodput: measured goodput under injected failures for a real
(smoke-scale) training run, plus the closed-form model at Gemini scale.

Paper anchors: Gemini 1.0 on TPU v4 = 97%; Gemini 2.5 multi-pod on
TPU v5p = 93%."""

import os
import shutil
import tempfile

from repro.core.goodput import modeled_goodput


def run(emit) -> None:
    # closed-form at paper scale: multi-pod job, 10-minute checkpoint
    # cadence, 2-minute restore, MTBF ~6h across the fleet
    g = modeled_goodput(mtbf_hours=6, detect_s=30, restore_s=120,
                        checkpoint_interval_s=600)
    emit("goodput/modeled_gemini_like", g, "paper: 0.93-0.97 band")
    g2 = modeled_goodput(mtbf_hours=24, detect_s=30, restore_s=120,
                         checkpoint_interval_s=600)
    emit("goodput/modeled_single_pod", g2, "paper: ~0.97 (Gemini 1.0)")

    # measured: smoke-scale run with injected failures
    from repro.launch.train import build_trainer
    from repro.configs.registry import get_smoke
    tmp = tempfile.mkdtemp(prefix="bench_goodput_")
    try:
        cfg = get_smoke("internlm2_1_8b")
        trainer, state = build_trainer(
            cfg, batch=4, seq=32, ckpt_dir=tmp, checkpoint_every=8,
            failures={13: 0, 21: 1})
        state, ledger, losses = trainer.run(state, 28)
        s = ledger.summary()
        emit("goodput/measured_2_failures_28_steps", s["goodput"],
             f"rework={s['rework_s']:.2f}s restore={s['restore_s']:.2f}s")
        emit("goodput/effective_steps", s["effective_steps"], "expect 28")
        rs = trainer.replay_summary()
        emit("goodput/replayed_steps", rs["replayed_steps"],
             f"of {rs['executions']} executions "
             f"(ckpt@8: failures 13,21 -> 5+5 replays)")
        emit("goodput/rescales", rs["rescales"],
             "real trainer restores at full scale (elastic arm is "
             "sim-only, see fleet suite)")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
