"""Table 1 + Intro scaling bullets: re-derive every claim from the spec
data and compare against the paper's stated numbers."""

from __future__ import annotations

from typing import List, Tuple

from repro.core import hwspec

# (metric, derived_value, paper_claim, tolerance_fraction)


def rows() -> List[Tuple[str, float, float, float]]:
    s = hwspec.scaling_summary()
    v2, v5p, iw = hwspec.TPU_V2, hwspec.TPU_V5P, hwspec.IRONWOOD
    out = [
        ("hbm_capacity_x", s["hbm_capacity_x"], 10.0, 0.25),
        ("hbm_bandwidth_x", s["hbm_bandwidth_x"], 10.0, 0.1),
        ("node_peak_x", s["node_peak_x"], 100.0, 0.05),
        ("node_peak_bf16_x", s["node_peak_bf16_x"], 50.0, 0.05),
        ("pod_size_x", s["pod_size_x"], 36.0, 0.01),
        ("bisection_x", s["bisection_x"], 39.0, 0.02),
        ("pod_hbm_x", s["pod_hbm_x"], 400.0, 0.1),
        ("pod_peak_x", s["pod_peak_x"], 3600.0, 0.01),
        ("perf_per_watt_x", s["perf_per_watt_x"], 30.0, 0.03),
    ]
    # bisection bandwidth absolute values (Table 1 row)
    for spec, claim in [(hwspec.TPU_V2, 1984), (hwspec.TPU_V3, 4480),
                        (hwspec.TPU_V4, 25600), (v5p, 64000), (iw, 76800)]:
        out.append((f"bisection_{spec.name}", spec.pod_bisection_gbps,
                    float(claim), 0.001))
    # pod peak ExaFLOPS row (the paper's 1-2 significant figures)
    for spec, claim in [(v2, 0.01), (hwspec.TPU_V3, 0.13),
                        (hwspec.TPU_V4, 1.1), (v5p, 4.1), (iw, 21.3)]:
        out.append((f"pod_bf16_EF_{spec.name}",
                    spec.pod_peak_bf16_exaflops, claim, 0.2))
    out.append(("pod_fp8_EF_ironwood", iw.pod_peak_fp8_exaflops, 42.5, 0.01))
    # pod HBM row ("PetaBytes" = kGiB in the paper's units)
    for spec, claim in [(v2, 4), (hwspec.TPU_V3, 33), (hwspec.TPU_V4, 131),
                        (v5p, 851), (iw, 1769)]:
        out.append((f"pod_hbm_{spec.name}", spec.pod_hbm_table_units,
                    float(claim), 0.03))
    return out


def run(emit) -> None:
    for name, derived, claim, tol in rows():
        ok = abs(derived - claim) <= tol * claim
        emit(f"table1/{name}", derived,
             f"paper={claim} {'OK' if ok else 'MISMATCH'}")
    s = hwspec.scaling_summary()
    # The paper says "nearly 100%" CAGR; 3600x over 8 years is actually
    # ~2.8x/year (178%) by the standard formula — we report the derived
    # value and flag the paper's arithmetic.
    emit("table1/cagr_pod_peak_derived", s["cagr_pod_peak"],
         "paper claims ~1.0 (see EXPERIMENTS.md note)")
