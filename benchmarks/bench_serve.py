"""Serve-engine benchmark: device-resident chunked decode vs the legacy
per-token loop, prefix caching + self-speculative decoding, and the
int8-vs-bf16 paged-decode capacity lever.

Five sections:

  1. static batch — chunked loop vs per-token loop (PR 1's win: one
     compiled program per chunk, one host sync per chunk);
  2. arrival trace — continuous batching under a synthetic multi-user
     trace (occupancy / preemptions, TTFT/TPOT percentiles and the
     prefill-vs-decode time split from the telemetry registry);
  3. shared-prefix batch — requests sharing a long prompt prefix served
     cold (PR 1 engine) vs with prefix caching + draft-k speculation.
     Reports prefix-cache hit rate, speculative acceptance length,
     cross-request dedup stats (pages shared / unique, bytes saved) and
     the per-token speedup (gate: >= 1.3x at batch 4);
  4. int8 vs bf16 paged decode — tokens/s for both pool dtypes,
     estimated HBM bytes/token streamed by paged attention, and the
     resident-batch capacity ratio (gate: int8 fits >= 1.5x the tokens);
  5. sharded serving — the same trace on a (data, model) mesh (forced
     fake host devices in a subprocess): tok/s vs single-host, plus
     disaggregated prefill/decode page-transfer traffic. On CPU the
     fake-device mesh pays real overhead, so tok/s is a wiring check,
     not a speedup claim (see docs/serving.md);
  6. MoE dispatch — sort-based grouped (dropless) vs the dense capacity
     buffer on the mixtral/kimi smoke MoE layers at a decode-shaped
     batch: tok/s plus the estimated HBM bytes/token each dispatch
     streams (gate: grouped beats capacity on mixtral).

  PYTHONPATH=src python benchmarks/bench_serve.py [--arch qwen2_0_5b]
      [--json]        # also write BENCH_serve.json
      [--smoke]       # fast interpret-mode kernel-routing check + the
                      # fatal sharded-parity gate (tier-1)

``benchmarks/run.py --only serve --json`` runs the same sections at
smoke scale through the CSV/JSON harness. See docs/benchmarks.md.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.launch.serve import make_trace
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


def bench_static_batch(engine, params, cfg, batch, max_new, reps=3):
    """Same fixed batch through both decode loops (compile excluded)."""
    engine.generate_pertoken(params, batch, max_new=2)  # warm
    t0 = time.time()
    for _ in range(reps):
        engine.generate_pertoken(params, batch, max_new=max_new)
    pertoken = reps * batch["tokens"].shape[0] * max_new / (time.time() - t0)

    engine.generate(params, batch, max_new=2)  # warm (compiles the chunk)
    t0 = time.time()
    for _ in range(reps):
        engine.generate(params, batch, max_new=max_new)
    chunked = reps * batch["tokens"].shape[0] * max_new / (time.time() - t0)
    return pertoken, chunked


def shared_prefix_requests(cfg, n, prefix_len, tail_len, max_new, seed):
    """n requests sharing a prompt prefix (system prompt / few-shot
    template shape) with small unique tails."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len)
    return [Request(
        rid=i,
        prompt=np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, tail_len)]),
        max_new=max_new) for i in range(n)]


def timed_run(engine, params, make_reqs, seed=0, reps=3):
    """Best-of-reps tokens/s: a shared CPU stalls individual reps by
    multiples, so the max is the stable estimate of sustained rate."""
    best = 0.0
    for _ in range(reps):
        reqs = make_reqs()
        t0 = time.time()
        out = engine.run(params, reqs, key=jax.random.key(seed))
        wall = time.time() - t0
        best = max(best, sum(len(v) for v in out.values()) / wall)
    return best


def bench_shared_prefix(cfg, ctx, params, *, batch, prefix_len, tail_len,
                        max_new, chunk, draft_k, seed):
    """Cold (PR 1) engine vs prefix-cache + speculative engine on the
    same shared-prefix batch. Both are warmed (compile excluded); the
    cached engine's warm run also populates the prefix index, so the
    timed run measures the steady serving state."""
    window = prefix_len + tail_len + max_new

    def reqs():
        return shared_prefix_requests(cfg, batch, prefix_len, tail_len,
                                      max_new, seed)

    base = ServeEngine(cfg, ctx, window=window, max_batch=batch,
                       chunk=chunk, prefix_cache=False)
    base.run(params, reqs())  # warm: compiles prefill + chunk
    base_tps = timed_run(base, params, reqs)

    eng = ServeEngine(cfg, ctx, window=window, max_batch=batch,
                      chunk=chunk, draft_k=draft_k)
    eng.run(params, reqs())  # warm 1: compiles + populates the index
    eng.run(params, reqs())  # warm 2: steady cached state
    for k in ("prompt_tokens", "cached_prompt_tokens", "spec_steps",
              "spec_tokens"):
        eng.counters[k] = 0
    for k in ("pages_shared", "pages_allocated"):
        eng.kv.counters[k] = 0  # dedup stats cover the timed window only
    cached_tps = timed_run(eng, params, reqs)
    return base_tps, cached_tps, eng


def bench_int8_vs_bf16(cfg, params, *, batch, prompt_len, max_new, chunk,
                       seed, reps=3):
    """int8 page pool vs bf16 on the same paged decode: tokens/s,
    estimated HBM bytes/token (full residency: each decode step streams
    the whole resident cache), and the capacity ratio — how many more
    tokens the int8 pool holds in the same HBM (scale pages included)."""
    window = prompt_len + max_new
    rng = np.random.default_rng(seed)
    toks = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    out = {}
    for tag, cdt in (("bf16", jnp.bfloat16), ("int8", jnp.int8)):
        ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=512,
                           decode_cache_dtype=cdt)
        eng = ServeEngine(cfg, ctx, window=window, max_batch=batch,
                          chunk=chunk, prefix_cache=False)
        eng.generate(params, toks, max_new=2)  # warm
        t0 = time.time()
        for _ in range(reps):
            eng.generate(params, toks, max_new=max_new)
        tps = reps * batch * max_new / (time.time() - t0)
        ptb = eng.kv.per_token_bytes()
        out[tag] = {"tok_s": tps, "per_token_bytes": ptb,
                    "est_hbm_bytes_per_token": window * ptb}
    out["capacity_ratio"] = (out["bf16"]["per_token_bytes"]
                             / out["int8"]["per_token_bytes"])
    return out


# -- MoE dispatch (section 6 + the tier-1 grouped-kernel gate) --------------


def _moe_hbm_bytes_per_token(cfg, t, mode, plan=None):
    """Estimated HBM bytes streamed per token by one MoE layer's
    dispatch at fp32. Capacity reads every expert's weights and writes/
    reads the dense (E, C, D) buffer; grouped reads one (D, F) weight
    tile per *used* m-tile plus the sorted M_pad row buffer."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    nw = 3 if cfg.mlp_act == "swiglu" else 2
    itemsize = 4
    if mode == "capacity":
        cap = t  # dropless: capacity == chunk token count
        weights = e * nw * d * f
        buffers = e * cap * (2 * d + f)  # scatter in, h, gather out
    else:
        used = int(np.sum(np.asarray(plan.block_experts) >= 0))
        weights = used * nw * d * f  # one weight tile DMA per used tile
        buffers = plan.padded_rows * (2 * d + f)
    return (weights + buffers) * itemsize / t


def bench_moe(emit, log, *, t=256, block_m=64, reps=20, seed=0):
    """Section 6: grouped vs capacity dispatch on the MoE smoke layers,
    decode-shaped (t single-token rows through one expert layer).
    ``block_m=64`` is the serving-scale m-tile: the dropless buffer is
    round_up(t*k + E*(block_m-1), block_m) rows vs capacity's E*t."""
    from repro.models.moe import (grouped_dispatch_plan, moe_ffn,
                                  moe_param_specs, _route)

    log(f"== [moe] grouped vs capacity dropless dispatch "
        f"(t={t} decode rows, block_m={block_m})")
    for arch in ("mixtral_8x22b", "kimi_k2_1t_a32b"):
        cfg = get_smoke(arch)
        p = init_params(jax.random.fold_in(jax.random.key(1), seed),
                        moe_param_specs(cfg))
        x = jax.random.normal(jax.random.key(2), (t, 1, cfg.d_model),
                              jnp.float32)
        tok_s = {}
        for tag, kw in (("grouped", {"dispatch": "grouped", "impl": "ref",
                                     "block_m": block_m}),
                        ("capacity", {"dropless": True})):
            fn = jax.jit(lambda pp, xx, kw=kw: moe_ffn(
                pp, xx, cfg, jnp.float32, **kw)[0])
            fn(p, x).block_until_ready()  # warm
            best = 0.0
            for _ in range(3):  # best-of-3: shared-CPU stall robustness
                t0 = time.time()
                for _ in range(reps):
                    out = fn(p, x)
                out.block_until_ready()
                best = max(best, reps * t / (time.time() - t0))
            tok_s[tag] = best
        _, _, _, gate_idx = _route(p, x.reshape(t, cfg.d_model),
                                   jnp.float32, cfg.experts_per_token)
        plan = grouped_dispatch_plan(gate_idx, n_experts=cfg.n_experts,
                                     block_m=block_m)
        hbm = {tag: _moe_hbm_bytes_per_token(cfg, t, tag, plan)
               for tag in ("grouped", "capacity")}
        speedup = tok_s["grouped"] / tok_s["capacity"]
        gated = arch == "mixtral_8x22b" and speedup <= 1.0
        for tag in ("grouped", "capacity"):
            emit(f"serve/moe_{arch}_{tag}_tok_s", tok_s[tag], "")
            emit(f"serve/moe_{arch}_{tag}_hbm_bytes_per_token", hbm[tag],
                 "")
        emit(f"serve/moe_{arch}_grouped_speedup", speedup,
             "FAILED: grouped <= capacity on mixtral" if gated
             else ("gate > 1.0x" if arch == "mixtral_8x22b" else ""))
        log(f"{arch}: grouped {tok_s['grouped']:8.1f} tok/s "
            f"({hbm['grouped']:.0f} B/token) | capacity "
            f"{tok_s['capacity']:8.1f} tok/s ({hbm['capacity']:.0f} "
            f"B/token) | {speedup:.2f}x")


def _moe_smoke() -> int:
    """Grouped-kernel==oracle gate (fatal, tier-1): the m-grouped GEMM
    kernel in interpret mode must match kernels/ref.grouped_matmul_ref
    for bf16 and int8(+scale) weights, and the grouped serving dispatch
    end-to-end (interpret kernel) must match capacity-dropless on the
    mixtral smoke MoE layer."""
    from repro.kernels import moe_gemm, ref as kref
    from repro.models.moe import (moe_ffn, moe_param_specs,
                                  quantize_moe_params)

    failures = 0
    key = jax.random.key(3)
    m, d, f, e = 64, 32, 48, 4
    gids = jnp.array([0, 0, 1, -1, 2, 3, 3, -1], jnp.int32)
    x32 = jax.random.normal(jax.random.fold_in(key, 0), (m, d))
    wf = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f))
    cases = {
        "bf16": (x32.astype(jnp.bfloat16), wf.astype(jnp.bfloat16), None,
                 2e-2),
        "int8": (x32, jnp.clip(jnp.round(wf * 40), -127,
                               127).astype(jnp.int8),
                 jnp.abs(jax.random.normal(jax.random.fold_in(key, 2),
                                           (e,))) + 0.1, 1e-5),
    }
    for tag, (x, w, scale, tol) in cases.items():
        got = moe_gemm.grouped_matmul(x, w, gids, w_scale=scale,
                                      interpret=True, block_f=16)
        want = kref.grouped_matmul_ref(x, w, gids, w_scale=scale)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        if err > tol:
            print(f"FAILED [moe {tag}]: grouped kernel != oracle "
                  f"(max|diff|={err:.2e} > {tol})")
            failures += 1
    cfg = get_smoke("mixtral_8x22b")
    p = quantize_moe_params(init_params(jax.random.fold_in(key, 4),
                                        moe_param_specs(cfg)))
    xm = jax.random.normal(jax.random.fold_in(key, 5),
                           (2, 5, cfg.d_model))
    got, _ = moe_ffn(p, xm, cfg, jnp.float32, dispatch="grouped",
                     impl="interpret")
    want, _ = moe_ffn(p, xm, cfg, jnp.float32, dropless=True)
    err = float(jnp.max(jnp.abs(got - want)))
    if err > 1e-4:
        print(f"FAILED [moe]: grouped dispatch != capacity dropless "
              f"(max|diff|={err:.2e})")
        failures += 1
    print(f"smoke [moe]: grouped kernel==oracle (bf16, int8) and "
          f"grouped==capacity-dropless on mixtral (max|diff|={err:.1e})")
    return failures


# -- sharded serving (section 5 + the tier-1 parity gate) -------------------
#
# The mesh needs multiple XLA devices, and jax locks the device count at
# first init — so everything sharded runs in a SUBPROCESS with
# --xla_force_host_platform_device_count in XLA_FLAGS. The parent invokes
# this same file with an inner flag; the child prints one JSON line.


def _run_sharded_child(flag: str, devices: int = 8,
                       timeout: int = 900) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, os.path.abspath(__file__), flag],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def sharded_smoke_inner() -> int:
    """Child process (forced multi-device): sharded-vs-single-host parity.

    Single-host jnp-oracle engine vs a (2, 2)-mesh engine routing the
    Pallas kernels (interpret mode) through shard_map — bf16-class and
    int8 pools, speculation on, run twice so the second pass decodes off
    prefix-cache hits. Then disaggregated vs co-located on the oracle
    path with nonzero modeled transfer traffic. Exact token match."""
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (9, 13)]
    reqs = lambda: [Request(rid=i, prompt=p, max_new=4)
                    for i, p in enumerate(prompts)]
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    failures = 0
    for cdt, tag in ((None, "fp32"), (jnp.int8, "int8")):
        octx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                            decode_cache_dtype=cdt)
        kctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                            decode_cache_dtype=cdt,
                            attn_impl="pallas_interpret")
        solo = ServeEngine(cfg, octx, window=32, max_batch=2, chunk=2,
                           page_size=8, draft_k=2)
        shard = ServeEngine(cfg, kctx, window=32, max_batch=2, chunk=2,
                            page_size=8, draft_k=2, mesh=mesh)
        for r in range(2):  # run 2 decodes off prefix-cache hits
            so, sh = solo.run(params, reqs()), shard.run(params, reqs())
            for i in range(len(prompts)):
                if not np.array_equal(so[i], sh[i]):
                    print(f"FAILED [{tag} run {r}]: sharded {sh[i]} != "
                          f"single-host {so[i]} (rid {i})")
                    failures += 1
        if shard.prefix_hit_rate <= 0:
            print(f"FAILED [{tag}]: sharded run 2 took no prefix hits")
            failures += 1
    co = ServeEngine(cfg, ModelContext(compute_dtype=jnp.float32,
                                       q_chunk=64),
                     window=32, max_batch=2, chunk=2, page_size=8)
    dis = ServeEngine(cfg, ModelContext(compute_dtype=jnp.float32,
                                        q_chunk=64),
                      window=32, max_batch=2, chunk=2, page_size=8,
                      disaggregate=True)
    coo, dio = co.run(params, reqs()), dis.run(params, reqs())
    for i in range(len(prompts)):
        if not np.array_equal(coo[i], dio[i]):
            print(f"FAILED [disagg]: {dio[i]} != co-located {coo[i]}")
            failures += 1
    if dis.transfer_stats()["transfer_bytes"] <= 0:
        print("FAILED [disagg]: no modeled transfer traffic")
        failures += 1
    print("SHARDED-PARITY", "FAILED" if failures else "OK",
          json.dumps(dis.transfer_stats()))
    return 1 if failures else 0


def sharded_bench_inner() -> int:
    """Child process (forced multi-device): section-5 measurements.
    Prints one JSON object: single-host vs (2, 2)-mesh trace tok/s and
    the disaggregated run's transfer traffic / stalls."""
    cfg = get_smoke("qwen2_0_5b")
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=512)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    window, chunk, trace = 28, 4, 8
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    out = {}

    def tps(eng):
        reqs = make_trace(trace, cfg.vocab_size, 0, prompt_hi=16, new_hi=12)
        eng.run(params, make_trace(2, cfg.vocab_size, 1, prompt_hi=16,
                                   new_hi=4))  # warm
        t0 = time.time()
        o = eng.run(params, reqs, key=jax.random.key(0))
        return sum(len(v) for v in o.values()) / (time.time() - t0)

    solo = ServeEngine(cfg, ctx, window=window, max_batch=4, chunk=chunk,
                       page_size=8)
    out["single_tok_s"] = tps(solo)
    shard = ServeEngine(cfg, ctx, window=window, max_batch=4, chunk=chunk,
                        page_size=8, mesh=mesh)
    out["sharded_tok_s"] = tps(shard)
    out["mesh"] = shard.sharding_report["mesh"]
    out["dropped_rules"] = shard.sharding_report["dropped_rules"]
    dis = ServeEngine(cfg, ctx, window=window, max_batch=4, chunk=chunk,
                      page_size=8, mesh=mesh, disaggregate=True,
                      prefill_workers=2)
    out["disagg_tok_s"] = tps(dis)
    ts = dis.transfer_stats()
    out["transfer_bytes"] = ts["transfer_bytes"]
    out["transfer_pages"] = ts["transfer_pages"]
    out["transfer_stall_boundaries"] = ts["transfer_stall_boundaries"]
    print(json.dumps(out))
    return 0


def bench_sharded(emit, log) -> None:
    """Section 5 driver: parse the child's JSON and emit metrics."""
    proc = _run_sharded_child("--sharded-bench-inner")
    if proc.returncode != 0:
        emit("serve/sharded_tok_s", 0.0,
             f"FAILED: child rc={proc.returncode}: {proc.stderr[-400:]}")
        return
    r = json.loads(proc.stdout.strip().splitlines()[-1])
    ratio = r["sharded_tok_s"] / r["single_tok_s"]
    emit("serve/single_host_tok_s", r["single_tok_s"], "")
    emit("serve/sharded_tok_s", r["sharded_tok_s"],
         f"mesh={r['mesh']} (fake CPU devices: wiring check)")
    emit("serve/sharded_vs_single", ratio, "")
    emit("serve/disagg_tok_s", r["disagg_tok_s"], "")
    emit("serve/disagg_transfer_bytes", r["transfer_bytes"],
         f"pages={r['transfer_pages']}" if r["transfer_bytes"] > 0
         else "FAILED: no transfer traffic")
    emit("serve/disagg_stall_boundaries", r["transfer_stall_boundaries"],
         "")
    log(f"sharded serving (mesh={r['mesh']}, fake CPU devices):")
    log(f"single host    : {r['single_tok_s']:8.1f} tok/s")
    log(f"sharded        : {r['sharded_tok_s']:8.1f} tok/s   "
        f"({ratio:.2f}x — CPU mesh overhead expected)")
    log(f"disaggregated  : {r['disagg_tok_s']:8.1f} tok/s   "
        f"{r['transfer_bytes']} transfer bytes, "
        f"{r['transfer_stall_boundaries']} stall boundaries")
    for line in r["dropped_rules"]:
        log(f"  fallback: {line}")


# Section-2 arrivals rows: (emitted row name, engine.slo_summary() key).
# A module constant so the golden-snapshot test
# (tests/test_serve_edge.py) pins the exact schema bench_serve exports
# for the arrivals workload — adding/renaming a row is a deliberate,
# test-visible change.
ARRIVALS_SLO_ROWS = (
    ("serve/ttft_p50_s", "ttft_p50_s"),
    ("serve/ttft_p95_s", "ttft_p95_s"),
    ("serve/tpot_p50_s", "tpot_p50_s"),
    ("serve/tpot_p95_s", "tpot_p95_s"),
    ("serve/queue_wait_p50_steps", "queue_wait_p50_steps"),
    ("serve/prefill_time_s", "prefill_time_s"),
    ("serve/decode_time_s", "decode_time_s"),
)


def run_sections(emit, *, arch="qwen2_0_5b", batch=4, prompt_len=16,
                 max_new=32, chunk=8, trace=12, prefix_len=448, tail_len=4,
                 prefix_max_new=12, draft_k=2, seed=0,
                 log=lambda *a: None):
    """All four sections through an ``emit(name, value, note)`` sink —
    shared by the CLI (pretty print + JSON) and benchmarks/run.py."""
    cfg = get_smoke(arch)
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=512,
                       mamba_chunk=16, rwkv_chunk=8)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    window = prompt_len + max_new
    engine = ServeEngine(cfg, ctx, window=window, max_batch=batch,
                         chunk=chunk, prefix_cache=False)
    mode = "paged" if engine.paged else "dense"
    rng = np.random.default_rng(seed)
    batch_toks = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    log(f"== bench_serve {arch} [{mode}] batch={batch} "
        f"chunk={chunk} max_new={max_new}")

    # 1. static batch ------------------------------------------------------
    pertoken, chunked = bench_static_batch(engine, params, cfg, batch_toks,
                                           max_new)
    speedup = chunked / pertoken
    emit("serve/pertoken_tok_s", pertoken, "")
    emit("serve/chunked_tok_s", chunked,
         "" if speedup > 1.0 else "FAILED: chunked <= per-token")
    emit("serve/chunked_speedup", speedup, f"host_syncs="
         f"{engine.counters['host_syncs']}")
    log(f"per-token loop : {pertoken:8.1f} tok/s")
    log(f"chunked loop   : {chunked:8.1f} tok/s   ({speedup:.2f}x)")

    # 2. arrival trace -----------------------------------------------------
    reqs = make_trace(trace, cfg.vocab_size, seed, prompt_hi=prompt_len,
                      new_hi=max_new)
    t0 = time.time()
    out = engine.run(params, reqs, key=jax.random.key(seed))
    wall = time.time() - t0
    toks = sum(len(v) for v in out.values())
    s = engine.scheduler
    emit("serve/trace_tok_s", toks / wall, f"{trace} reqs")
    emit("serve/trace_occupancy", s.mean_occupancy,
         " ".join(f"{k}={v}" for k, v in s.stats.items()))
    log(f"trace ({trace} reqs): {toks} tokens in {wall:.2f}s "
        f"({toks / wall:.1f} tok/s)  occupancy={s.mean_occupancy:.2f}")
    # SLO percentiles on the arrivals workload, straight off the
    # engine's telemetry registry (TTFT is measured at chunk drain, so
    # its floor is one chunk of decode on this host)
    slo = engine.slo_summary()
    notes = {"serve/ttft_p50_s": "measured at chunk drain",
             "serve/prefill_time_s": f"{slo['prefill_tok_s']:.0f} tok/s",
             "serve/decode_time_s": f"{slo['decode_tok_s']:.0f} tok/s"}
    for row, key in ARRIVALS_SLO_ROWS:
        emit(row, slo[key], notes.get(row, ""))
    log(f"slo: ttft p50={slo['ttft_p50_s'] * 1e3:.1f}ms "
        f"p95={slo['ttft_p95_s'] * 1e3:.1f}ms | "
        f"tpot p50={slo['tpot_p50_s'] * 1e3:.2f}ms "
        f"p95={slo['tpot_p95_s'] * 1e3:.2f}ms | "
        f"queue p50={slo['queue_wait_p50_steps']:.0f} steps | "
        f"prefill {slo['prefill_time_s']:.2f}s / "
        f"decode {slo['decode_time_s']:.2f}s")
    global _LAST_SNAPSHOT
    _LAST_SNAPSHOT = engine.metrics.snapshot()

    if not engine.paged:
        return

    # 3. shared-prefix batch ----------------------------------------------
    base_tps, cached_tps, eng = bench_shared_prefix(
        cfg, ctx, params, batch=batch, prefix_len=prefix_len,
        tail_len=tail_len, max_new=prefix_max_new, chunk=chunk,
        draft_k=draft_k, seed=seed)
    prefix_speedup = cached_tps / base_tps
    dedup = eng.kv.dedup_stats()
    emit("serve/prefix_cold_tok_s", base_tps, "")
    emit("serve/prefix_cached_tok_s", cached_tps,
         "" if prefix_speedup >= 1.3 else "FAILED: below 1.3x gate")
    emit("serve/prefix_speedup", prefix_speedup, "gate >= 1.3x")
    emit("serve/prefix_hit_rate", eng.prefix_hit_rate, "")
    emit("serve/acceptance_length", eng.acceptance_length, "")
    emit("serve/dedup_pages_shared", dedup["pages_shared"], "")
    emit("serve/dedup_pages_unique", dedup["pages_unique"], "")
    emit("serve/dedup_bytes_saved", dedup["bytes_saved"], "")
    log(f"shared-prefix batch (prefix={prefix_len} tail={tail_len} "
        f"draft_k={draft_k}):")
    log(f"cold engine    : {base_tps:8.1f} tok/s")
    log(f"cached+spec    : {cached_tps:8.1f} tok/s   "
        f"({prefix_speedup:.2f}x)")
    log(f"prefix hit rate: {eng.prefix_hit_rate:.2f}   "
        f"acceptance length: {eng.acceptance_length:.2f}")
    log(f"dedup: {dedup['pages_shared']} pages shared / "
        f"{dedup['pages_unique']} unique, "
        f"{dedup['bytes_saved']} bytes saved")

    # 4. int8 vs bf16 paged decode ----------------------------------------
    q = bench_int8_vs_bf16(cfg, params, batch=batch, prompt_len=prompt_len,
                           max_new=max_new, chunk=chunk, seed=seed)
    cap = q["capacity_ratio"]
    for tag in ("bf16", "int8"):
        emit(f"serve/{tag}_tok_s", q[tag]["tok_s"], "")
        emit(f"serve/{tag}_hbm_bytes_per_token",
             q[tag]["est_hbm_bytes_per_token"],
             f"per_token_bytes={q[tag]['per_token_bytes']}")
    emit("serve/int8_capacity_ratio", cap,
         "gate >= 1.5x" if cap >= 1.5 else "FAILED: below 1.5x capacity")
    log(f"int8 vs bf16 paged decode:")
    log(f"bf16 pool      : {q['bf16']['tok_s']:8.1f} tok/s   "
        f"{q['bf16']['est_hbm_bytes_per_token']} B/token")
    log(f"int8 pool      : {q['int8']['tok_s']:8.1f} tok/s   "
        f"{q['int8']['est_hbm_bytes_per_token']} B/token")
    log(f"capacity ratio : {cap:.2f}x resident tokens per HBM byte")

    # 5. sharded serving (subprocess: needs a multi-device mesh) ----------
    bench_sharded(emit, log)

    # 6. MoE dispatch: grouped (dropless sort) vs capacity buffer ---------
    bench_moe(emit, log, seed=seed)


# last arrivals-workload registry snapshot, exported to run.py --json
# under the BENCH_serve.json "metrics" key (see metrics_snapshot())
_LAST_SNAPSHOT: dict = {}


def metrics_snapshot() -> dict:
    """run.py --json hook: the arrivals-workload engine's final
    telemetry-registry snapshot (counters + SLO histograms)."""
    return _LAST_SNAPSHOT


def run(emit):
    """benchmarks/run.py suite entry (smoke scale, CSV/JSON harness).

    The shared prefix stays long relative to the decode budget — the
    system-prompt traffic shape the 1.3x gate is defined over (chunked
    cold prefill made short-prompt admission cheap enough that a short
    prefix no longer shows the cache win)."""
    run_sections(emit, batch=2, prompt_len=12, max_new=12, chunk=4,
                 trace=6, prefix_len=384, tail_len=4, prefix_max_new=8,
                 draft_k=2)


def _fault_smoke() -> int:
    """Fault-injection gate (fatal, tier-1): a disaggregated engine under
    an injected schedule (prefill-worker kill + KV page flips + transfer
    drops + stragglers) must (a) detect nonzero faults, (b) finish most
    requests, and (c) emit byte-identical tokens for every survivor vs
    the fault-free run on the same seed — the replay-recovery parity
    contract (see docs/serving.md, Faults and degradation)."""
    from repro.serve.faults import FaultInjector, FaultPlan

    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (9, 13, 11)]
    mk = lambda: [Request(rid=i, prompt=p, max_new=4)
                  for i, p in enumerate(prompts)]

    def build(faults=None):
        return ServeEngine(cfg, ctx, window=32, max_batch=2, chunk=2,
                           page_size=8, disaggregate=True,
                           prefill_workers=2, faults=faults)

    failures = 0
    base = build().run(params, mk())
    inj = FaultInjector(FaultPlan(
        seed=7, worker_fail_rate=0.25, page_flip_rate=0.25,
        transfer_drop_rate=0.2, straggler_rate=0.2))
    eng = build(inj)
    out = eng.run(params, mk())
    fs = eng.fault_stats
    injected = (fs["fault_worker_failures"] + fs["fault_page_corruptions"]
                + fs["fault_transfer_drops"] + fs["fault_stragglers"])
    if injected == 0:
        print("FAILED [faults]: schedule injected nothing")
        failures += 1
    if fs["fault_page_corruptions"] > 0 and fs["fault_detections"] == 0:
        print("FAILED [faults]: page corruptions went undetected")
        failures += 1
    if len(out) < 2:
        print(f"FAILED [faults]: only {len(out)}/{len(prompts)} requests "
              "survived the schedule")
        failures += 1
    for rid, toks in out.items():
        if not np.array_equal(toks, base[rid]):
            print(f"FAILED [faults]: survivor rid {rid} diverged: "
                  f"{toks} != fault-free {base[rid]}")
            failures += 1
    print(f"smoke [faults]: {len(out)}/{len(prompts)} survivors "
          f"token-identical under "
          + " ".join(f"{k.split('fault_')[-1]}={fs[k]}" for k in (
              "fault_worker_failures", "fault_page_corruptions",
              "fault_transfer_drops", "fault_stragglers",
              "fault_detections", "retry_requeues")))
    return failures


def run_smoke() -> int:
    """Fast interpret-mode kernel-routing gate for tier-1: every paged
    serving path (chunked cold prefill, suffix prefill, spec verify,
    decode) through the Pallas kernels — fp AND int8 — must reproduce
    the jnp gather-dequant oracle engine exactly, with one span-prefill
    compile."""
    cfg = get_smoke("qwen2_0_5b")
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (9, 13)]
    failures = 0
    for cdt, tag in ((None, "fp32"), (jnp.int8, "int8")):
        kctx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                            decode_cache_dtype=cdt,
                            attn_impl="pallas_interpret")
        octx = ModelContext(compute_dtype=jnp.float32, q_chunk=64,
                            decode_cache_dtype=cdt)
        kern = ServeEngine(cfg, kctx, window=32, max_batch=2, chunk=2,
                           page_size=8, draft_k=2)
        orac = ServeEngine(cfg, octx, window=32, max_batch=2, chunk=2,
                           page_size=8, draft_k=2)
        compiles = None
        for r in range(3):  # run 2 exercises the cached-suffix span;
            # run 3 must hit only already-compiled span programs
            reqs = lambda: [Request(rid=i, prompt=p, max_new=4)
                            for i, p in enumerate(prompts)]
            ko = kern.run(params, reqs())
            oo = orac.run(params, reqs())
            for i in range(len(prompts)):
                if not np.array_equal(ko[i], oo[i]):
                    print(f"FAILED [{tag} run {r}]: kernel {ko[i]} != "
                          f"oracle {oo[i]} (rid {i})")
                    failures += 1
            if r == 1:
                compiles = kern.counters["span_prefill_compiles"]
        if kern.counters["span_prefill_compiles"] != compiles:
            print(f"FAILED [{tag}]: span-prefill program family grew "
                  f"({compiles} -> "
                  f"{kern.counters['span_prefill_compiles']})")
            failures += 1
        print(f"smoke [{tag}]: kernel==oracle over "
              f"{sum(len(p) for p in prompts)} prompt + 8 decode tokens, "
              f"{compiles} span-prefill programs (stable)")
    # grouped-MoE gate (fatal): m-grouped GEMM kernel == jnp oracle
    # (bf16 + int8) and grouped dispatch == capacity-dropless
    failures += _moe_smoke()
    # fault-injection parity gate (fatal): survivors of an injected
    # fault schedule must match the fault-free run byte for byte
    failures += _fault_smoke()
    # sharded-parity gate (fatal): mesh decode == single-host decode,
    # disaggregated == co-located — in a forced-multi-device subprocess
    proc = _run_sharded_child("--sharded-smoke-inner")
    tail = proc.stdout.strip().splitlines()
    print(tail[-1] if tail else "(sharded child produced no output)")
    if proc.returncode != 0:
        print(f"FAILED [sharded]: child rc={proc.returncode}\n"
              f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
        failures += 1
    print("bench_serve --smoke:", "FAILED" if failures else "PASSED")
    return 1 if failures else 0


def main() -> None:
    if "--sharded-smoke-inner" in sys.argv:
        sys.exit(sharded_smoke_inner())
    if "--sharded-bench-inner" in sys.argv:
        sys.exit(sharded_bench_inner())
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--trace", type=int, default=12)
    ap.add_argument("--prefix-len", type=int, default=448,
                    help="shared prompt prefix for section 3")
    ap.add_argument("--tail-len", type=int, default=4)
    ap.add_argument("--prefix-max-new", type=int, default=12,
                    help="decode budget for section 3 (prefill-heavy by "
                         "design: the system-prompt traffic shape)")
    ap.add_argument("--draft-k", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="fast interpret-mode kernel-routing gate "
                         "(tier-1); skips the timing sections")
    args = ap.parse_args()

    if args.smoke:
        sys.exit(run_smoke())

    rows = []
    failed = []

    def emit(name, value, note=""):
        rows.append({"name": name, "value": value, "note": note})
        if "FAILED" in note:
            failed.append(name)

    run_sections(emit, arch=args.arch, batch=args.batch,
                 prompt_len=args.prompt_len, max_new=args.max_new,
                 chunk=args.chunk, trace=args.trace,
                 prefix_len=args.prefix_len, tail_len=args.tail_len,
                 prefix_max_new=args.prefix_max_new, draft_k=args.draft_k,
                 seed=args.seed, log=print)
    if args.json:
        with open("BENCH_serve.json", "w") as f:
            json.dump({"suite": "serve", "rows": rows,
                       "metrics": metrics_snapshot()}, f, indent=1,
                      default=str)
        print("wrote BENCH_serve.json")
    if failed:
        print(f"WARNING: gates failed: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
