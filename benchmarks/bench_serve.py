"""Serve-engine benchmark: device-resident chunked decode vs the legacy
per-token loop, under a synthetic multi-user arrival trace.

Reports tokens/s for both paths and the continuous-batching engine's mean
batch occupancy / preemption counts. The chunked loop wins because the
whole decode chunk is one compiled program: no per-token Python dispatch,
no per-token host sync.

  PYTHONPATH=src python benchmarks/bench_serve.py [--arch qwen2_0_5b]
"""

from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.launch.serve import make_trace
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


def bench_static_batch(engine, params, cfg, batch, max_new, reps=3):
    """Same fixed batch through both decode loops (compile excluded)."""
    engine.generate_pertoken(params, batch, max_new=2)  # warm
    t0 = time.time()
    for _ in range(reps):
        engine.generate_pertoken(params, batch, max_new=max_new)
    pertoken = reps * batch["tokens"].shape[0] * max_new / (time.time() - t0)

    engine.generate(params, batch, max_new=2)  # warm (compiles the chunk)
    t0 = time.time()
    for _ in range(reps):
        engine.generate(params, batch, max_new=max_new)
    chunked = reps * batch["tokens"].shape[0] * max_new / (time.time() - t0)
    return pertoken, chunked


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--trace", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=512,
                       mamba_chunk=16, rwkv_chunk=8)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    window = args.prompt_len + args.max_new
    engine = ServeEngine(cfg, ctx, window=window, max_batch=args.batch,
                         chunk=args.chunk)
    mode = "paged" if engine.paged else "dense"
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}

    print(f"== bench_serve {args.arch} [{mode}] batch={args.batch} "
          f"chunk={args.chunk} max_new={args.max_new}")
    pertoken, chunked = bench_static_batch(engine, params, cfg, batch,
                                           args.max_new)
    speedup = chunked / pertoken
    print(f"per-token loop : {pertoken:8.1f} tok/s")
    print(f"chunked loop   : {chunked:8.1f} tok/s   ({speedup:.2f}x)")
    print(f"host syncs     : chunked={engine.counters['host_syncs']} "
          f"vs per-token dispatches={engine.counters['pertoken_steps']}")

    # continuous batching under an arrival trace
    reqs = make_trace(args.trace, cfg.vocab_size, args.seed,
                      prompt_hi=args.prompt_len, new_hi=args.max_new)
    t0 = time.time()
    out = engine.run(params, reqs, key=jax.random.key(args.seed))
    wall = time.time() - t0
    toks = sum(len(v) for v in out.values())
    s = engine.scheduler
    print(f"trace ({args.trace} reqs): {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s)")
    print(f"batch occupancy: {s.mean_occupancy:.2f}  stats: {s.stats}")
    if speedup <= 1.0:
        print("WARNING: chunked loop did not beat per-token loop")
        sys.exit(1)


if __name__ == "__main__":
    main()
