"""Serve-engine benchmark: device-resident chunked decode vs the legacy
per-token loop, plus prefix caching + self-speculative decoding on a
shared-prefix batch (the system-prompt traffic shape).

Three sections:

  1. static batch — chunked loop vs per-token loop (PR 1's win: one
     compiled program per chunk, one host sync per chunk);
  2. arrival trace — continuous batching under a synthetic multi-user
     trace (occupancy / preemptions);
  3. shared-prefix batch — requests sharing a long prompt prefix served
     cold (PR 1 engine) vs with prefix caching + draft-k speculation.
     Reports prefix-cache hit rate, speculative acceptance length, and
     the per-token speedup (gate: >= 1.3x at batch 4).

  PYTHONPATH=src python benchmarks/bench_serve.py [--arch qwen2_0_5b]

See docs/benchmarks.md for every entry point's paper anchor.
"""

from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.launch.serve import make_trace
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


def bench_static_batch(engine, params, cfg, batch, max_new, reps=3):
    """Same fixed batch through both decode loops (compile excluded)."""
    engine.generate_pertoken(params, batch, max_new=2)  # warm
    t0 = time.time()
    for _ in range(reps):
        engine.generate_pertoken(params, batch, max_new=max_new)
    pertoken = reps * batch["tokens"].shape[0] * max_new / (time.time() - t0)

    engine.generate(params, batch, max_new=2)  # warm (compiles the chunk)
    t0 = time.time()
    for _ in range(reps):
        engine.generate(params, batch, max_new=max_new)
    chunked = reps * batch["tokens"].shape[0] * max_new / (time.time() - t0)
    return pertoken, chunked


def shared_prefix_requests(cfg, n, prefix_len, tail_len, max_new, seed):
    """n requests sharing a prompt prefix (system prompt / few-shot
    template shape) with small unique tails."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len)
    return [Request(
        rid=i,
        prompt=np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, tail_len)]),
        max_new=max_new) for i in range(n)]


def timed_run(engine, params, make_reqs, seed=0, reps=3):
    toks, wall = 0, 0.0
    for _ in range(reps):
        reqs = make_reqs()
        t0 = time.time()
        out = engine.run(params, reqs, key=jax.random.key(seed))
        wall += time.time() - t0
        toks += sum(len(v) for v in out.values())
    return toks / wall


def bench_shared_prefix(cfg, ctx, params, *, batch, prefix_len, tail_len,
                        max_new, chunk, draft_k, seed):
    """Cold (PR 1) engine vs prefix-cache + speculative engine on the
    same shared-prefix batch. Both are warmed (compile excluded); the
    cached engine's warm run also populates the prefix index, so the
    timed run measures the steady serving state."""
    window = prefix_len + tail_len + max_new

    def reqs():
        return shared_prefix_requests(cfg, batch, prefix_len, tail_len,
                                      max_new, seed)

    base = ServeEngine(cfg, ctx, window=window, max_batch=batch,
                       chunk=chunk, prefix_cache=False)
    base.run(params, reqs())  # warm: compiles prefill + chunk
    base_tps = timed_run(base, params, reqs)

    eng = ServeEngine(cfg, ctx, window=window, max_batch=batch,
                      chunk=chunk, draft_k=draft_k)
    eng.run(params, reqs())  # warm 1: compiles + populates the index
    eng.run(params, reqs())  # warm 2: compiles the cached-suffix span
    for k in ("prompt_tokens", "cached_prompt_tokens", "spec_steps",
              "spec_tokens"):
        eng.counters[k] = 0
    cached_tps = timed_run(eng, params, reqs)
    return base_tps, cached_tps, eng


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--trace", type=int, default=12)
    ap.add_argument("--prefix-len", type=int, default=448,
                    help="shared prompt prefix for section 3")
    ap.add_argument("--tail-len", type=int, default=4)
    ap.add_argument("--prefix-max-new", type=int, default=12,
                    help="decode budget for section 3 (prefill-heavy by "
                         "design: the system-prompt traffic shape)")
    ap.add_argument("--draft-k", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=512,
                       mamba_chunk=16, rwkv_chunk=8)
    params = init_params(jax.random.key(0), api.model_specs(cfg))
    window = args.prompt_len + args.max_new
    # sections 1-2 measure the plain chunked loop (PR 1 behavior): no
    # prefix cache, so re-runs of one batch time identical work
    engine = ServeEngine(cfg, ctx, window=window, max_batch=args.batch,
                         chunk=args.chunk, prefix_cache=False)
    mode = "paged" if engine.paged else "dense"
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}

    print(f"== bench_serve {args.arch} [{mode}] batch={args.batch} "
          f"chunk={args.chunk} max_new={args.max_new}")
    pertoken, chunked = bench_static_batch(engine, params, cfg, batch,
                                           args.max_new)
    speedup = chunked / pertoken
    print(f"per-token loop : {pertoken:8.1f} tok/s")
    print(f"chunked loop   : {chunked:8.1f} tok/s   ({speedup:.2f}x)")
    print(f"host syncs     : chunked={engine.counters['host_syncs']} "
          f"vs per-token dispatches={engine.counters['pertoken_steps']}")

    # continuous batching under an arrival trace
    reqs = make_trace(args.trace, cfg.vocab_size, args.seed,
                      prompt_hi=args.prompt_len, new_hi=args.max_new)
    t0 = time.time()
    out = engine.run(params, reqs, key=jax.random.key(args.seed))
    wall = time.time() - t0
    toks = sum(len(v) for v in out.values())
    s = engine.scheduler
    print(f"trace ({args.trace} reqs): {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s)")
    print(f"batch occupancy: {s.mean_occupancy:.2f}  stats: {s.stats}")

    # prefix caching + speculative decoding on a shared-prefix batch
    prefix_speedup = None
    if engine.paged:
        base_tps, cached_tps, eng = bench_shared_prefix(
            cfg, ctx, params, batch=args.batch,
            prefix_len=args.prefix_len, tail_len=args.tail_len,
            max_new=args.prefix_max_new, chunk=args.chunk,
            draft_k=args.draft_k, seed=args.seed)
        prefix_speedup = cached_tps / base_tps
        print(f"shared-prefix batch (prefix={args.prefix_len} "
              f"tail={args.tail_len} draft_k={args.draft_k}):")
        print(f"cold engine    : {base_tps:8.1f} tok/s")
        print(f"cached+spec    : {cached_tps:8.1f} tok/s   "
              f"({prefix_speedup:.2f}x)")
        print(f"prefix hit rate: {eng.prefix_hit_rate:.2f}   "
              f"acceptance length: {eng.acceptance_length:.2f}")

    failed = False
    if speedup <= 1.0:
        print("WARNING: chunked loop did not beat per-token loop")
        failed = True
    if prefix_speedup is not None and prefix_speedup < 1.3:
        print("WARNING: cached+speculative below the 1.3x gate")
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
