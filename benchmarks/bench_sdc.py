"""§Resilience SDC: FBIST screens a simulated fleet with one marginal chip;
the replay checker catches an injected intermittent lane fault. Paper:
Ironwood's FBIST + VPU replay "identified defective units that evaded all
other screening methods"."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sdc import (FBIST, FaultModel, ReplayChecker, faulty_wrap,
                            screen_devices)


def run(emit) -> None:
    good = lambda a, b: a @ b
    fbist = FBIST(m=128, k=128, n=128, n_patterns=8)
    rep = fbist.run(good)
    emit("sdc/fbist_healthy_pass", int(rep.passed),
         f"max_err={rep.max_abs_err:.2e}")

    # fleet of 16 devices, one with a marginal datapath
    fleet = [good] * 16
    fleet[11] = faulty_wrap(good, FaultModel(rate=1.0, magnitude=0.3,
                                             seed=3))
    bad = screen_devices(fleet, fbist=fbist)
    emit("sdc/fbist_flagged_device", bad[0] if bad else -1,
         "expect 11 (mapped out via OCS)")

    # replay checker: elementwise op with an intermittent bad lane
    checker = ReplayChecker(sample_frac=0.25)
    x = jax.random.normal(jax.random.key(0), (256, 128))
    ok = checker.check(jnp.tanh, x, jax.random.key(1))
    emit("sdc/replay_healthy_pass", int(ok.passed),
         f"bundles={ok.bundles_checked}")

    def bad_lane(v):
        out = jnp.tanh(v)
        return out.at[..., 7].mul(1.0 + 1e-3)  # lane 7 mis-multiplies

    caught = 0
    for i in range(8):
        r = checker.check(bad_lane, x, jax.random.key(10 + i))
        caught += not r.passed
    emit("sdc/replay_caught_bad_lane", caught,
         "expect 8/8 (lane flip breaks replay equality)")
