"""Figure 6 + §Sustainability: compute carbon intensity and the GPT-3
worked example; checks every relation the paper states about CCI."""

from repro.core import cci


def run(emit) -> None:
    v4, v5p, iw = cci.CCI_TPU_V4, cci.CCI_TPU_V5P, cci.CCI_IRONWOOD
    checks = [
        ("v5p_total_market", v5p.total_market, 265.0, 0.02),
        ("v4_over_v5p_total", v4.total_market / v5p.total_market, 1.1, 0.05),
        ("v4_over_v5p_operational",
         v4.operational_market / v5p.operational_market, 1.1, 0.05),
        ("v4_over_v5p_embodied", v4.embodied / v5p.embodied, 1.3, 0.05),
        ("v5p_over_iw_operational",
         v5p.operational_market / iw.operational_market, 3.7, 0.05),
        ("v5p_over_iw_embodied", v5p.embodied / iw.embodied, 3.8, 0.05),
        ("iw_embodied_share_market", iw.embodied_share_market, 0.23, 0.1),
        ("iw_embodied_share_location", iw.embodied_share_location,
         0.08, 0.15),
        # footnote 7 location-based operational values
        ("v4_op_location", v4.operational_location, 793.0, 0.01),
        ("v5p_op_location", v5p.operational_location, 712.0, 0.01),
        ("iw_op_location", iw.operational_location, 195.0, 0.01),
    ]
    for name, val, claim, tol in checks:
        ok = abs(val - claim) <= tol * claim
        emit(f"fig6/{name}", val,
             f"paper={claim} {'OK' if ok else 'MISMATCH'}")
    # operational share ~75% for all three (market-based)
    for rec in (v4, v5p, iw):
        share = rec.operational_market / rec.total_market
        emit(f"fig6/op_share_{rec.tpu}", share,
             f"paper=~0.75 {'OK' if 0.68 < share < 0.82 else 'MISMATCH'}")
    # GPT-3 ballpark: 3.14e23 FLOPs x v5p CCI -> ~8.3e7 g
    grams = cci.emissions_grams(3.14e23, v5p)
    emit("sustainability/gpt3_gco2e", grams,
         f"paper=~8.3e7 g {'OK' if 7.8e7 < grams < 8.8e7 else 'MISMATCH'} "
         "(83 tCO2e; the paper's 'million metric tons' is a unit slip)")
