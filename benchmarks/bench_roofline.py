"""§Roofline: render the dry-run's per-cell roofline table from
results/dryrun/dryrun.jsonl (produced by repro.launch.dryrun). Emits one
row per (arch x shape x mesh) with the three terms, the dominant bound,
MODEL_FLOPS/HLO ratio, and the napkin (TPU-projected) terms."""

import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun", "dryrun.jsonl")


def load_records(path: str = RESULTS):
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as fh:
        for line in fh:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return list(recs.values())


def run(emit) -> None:
    recs = load_records()
    if not recs:
        emit("roofline/missing", 0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all "
             "--both-meshes --out results/dryrun")
        return
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    emit("roofline/cells_ok", n_ok, f"skipped={n_skip} "
         f"failed={len(recs) - n_ok - n_skip}")
    for r in sorted(recs, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        key = f"{r['mesh']}/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            emit(f"roofline/{key}", -1, "SKIP: " + r["skip_reason"][:60])
            continue
        if r["status"] != "ok":
            emit(f"roofline/{key}", -1, "FAILED")
            continue
        rf = r["roofline"]
        nap = r.get("napkin", {})
        emit(f"roofline/{key}", rf["roofline_frac"],
             f"bound={rf['bound']} t=({rf['t_compute_s']}|"
             f"{rf['t_memory_s']}|{rf['t_collective_s']})s "
             f"napkin={nap.get('bound', '?')}"
             f"({nap.get('t_compute_s', 0)}|{nap.get('t_memory_s', 0)}|"
             f"{nap.get('t_collective_s', 0)})s "
             f"useful={rf['useful_ratio']} mem={rf['mem_gib_per_chip']}GiB")
