"""§Resilience + §Sustainability at fleet scale: the discrete-event
simulator reproducing the paper's goodput anchors (Gemini 1.0 on TPU v4
~97%; Gemini 2.5 multi-pod on TPU v5p ~93%), the Ironwood 4x2K-job
spare-cube scenario, the OCS-vs-contiguous resilience gap, the
Ironwood-vs-v2 sustainability ratio from the anchored TDP chain, the
sim-vs-ResilientTrainer bridge — and the elastic scenario suite:
re-scale-vs-queue goodput gap, incremental deployment
(``set_installed`` over time), slice-size-vs-schedulability curves,
roofline-fed per-generation step times, and checkpoint-write contention
with the sim-vs-Young/Daly interval validation.

The serve side rides the same suite: every scenario JSON under
``benchmarks/scenarios/`` (SLO-goodput serve jobs, mixed serve+train
pods, autoscale-vs-static and burst-violation gates) runs as one
``fleet/scenario_*`` row with its ``expect`` assertions, and the same
files run as pytest cases (``tests/test_fleet_serve.py``).

Runs as the ``fleet`` suite of ``benchmarks/run.py`` (``--json`` writes
``BENCH_fleet.json``; see docs/benchmarks.md for the row schema), or
standalone:

  PYTHONPATH=src python benchmarks/bench_fleet.py --smoke   # tier-1 gate

``--smoke`` runs the deterministic short-horizon elastic scenario (same
seed and failure trace for both arms) asserting the re-scale arm beats
queue-only on goodput AND steps, a reduced checkpoint-interval sweep
asserting sim-vs-model agreement within one grid bucket, and the serve
gates: autoscaling-beats-static and SLO-violation-under-burst scenario
suites, a byte-identical determinism double-run, and the steptrace
calibration round-trip (``serve_calibration_check``).
"""

import argparse
import json
import sys
from pathlib import Path

from repro.core import hwspec
from repro.core.sdc import SDCRateModel
from repro.fleet import (FleetConfig, FleetSimulator, JobSpec,
                         PowerModel, StepTimeModel, TrainWorkload,
                         generation_step_times, grammar_ok,
                         job_spec_from_roofline, load_scenario,
                         load_scenario_paths, run_bridge, run_scenario,
                         search_checkpoint_interval,
                         serve_calibration_check,
                         sim_checkpoint_interval_sweep,
                         sustainability_ratios)
from repro.obs.steptrace import StepTrace

SCENARIO_DIR = Path(__file__).resolve().parent / "scenarios"

_DAY = 86400.0
_HOUR = 3600.0

# the worked workload for the roofline-fed sections: a 70B dense model at
# a 16M-token global batch (Gemini-era shapes)
_WORKLOAD = TrainWorkload(n_params=70e9, tokens_per_step=4096 * 4096)


def _one_job_goodput(tpu, total_cubes, chips, host_mtbf_hours, days=4.0,
                     seed=1):
    cfg = FleetConfig(tpu=tpu, total_cubes=total_cubes,
                      host_mtbf_hours=host_mtbf_hours, seed=seed)
    # 2 s steps, snapshot every 300 steps = the paper-era 10-minute cadence
    job = JobSpec(name="gem", chips=chips, total_steps=10**9,
                  step_time_s=2.0, checkpoint_every_steps=300)
    sim = FleetSimulator(cfg, [job])
    sim.run(days * _DAY)
    return sim


# ---------------------------------------------------------------------------
# Elastic: re-scale-on-starvation vs queue-only, same seed + failure trace.
# ---------------------------------------------------------------------------


def _elastic_arm(policy, *, seed=9, days=2.0):
    """A deliberately tight pod: three 6-cube jobs on 20 cubes leaves two
    spares, failures outpace the 8 h repairs, so starvation happens. The
    failure trace is independent of the job timeline, so both arms see
    the identical trace."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=20, host_mtbf_hours=150.0,
                      repair_hours=8.0, seed=seed)
    jobs = [JobSpec(name=f"j{i}", chips=6 * 64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=300,
                    scale_policy=policy, min_cubes=2 if policy == "shrink"
                    else 0)
            for i in range(3)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(days * _DAY)
    return sim


def _elastic_smoke_arm(policy):
    """Deterministic single-failure scenario for the tier-1 smoke gate:
    j0 (3 cubes) loses a cube at step 1000 with zero spares. The queue
    arm waits out the 2 h repair; the shrink arm keeps stepping on its
    two surviving cubes and grows back after the repair."""
    cfg = FleetConfig(tpu="tpu_v4", total_cubes=4, host_mtbf_hours=None,
                      repair_hours=2.0)
    jobs = [JobSpec(name="j0", chips=3 * 64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=300,
                    scale_policy=policy,
                    min_cubes=1 if policy == "shrink" else 0,
                    failure_steps=((1000, -1),)),
            JobSpec(name="j1", chips=64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=300)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(4 * _HOUR)
    return sim


def _emit_elastic(emit):
    queue, shrink = _elastic_arm("queue"), _elastic_arm("shrink")
    qf, sf = queue.fleet_summary(), shrink.fleet_summary()
    note = (f"{sf['rescales']:.0f} re-scales, "
            f"{sf['grow_backs']:.0f} grow-backs vs "
            f"{qf['starvations']:.0f} queue starvations, same trace")
    if not (sf["mean_goodput"] > qf["mean_goodput"]
            and qf["starvations"] > 0 and sf["rescales"] > 0):
        note += " MISMATCH"
    emit("fleet/elastic_vs_queue_goodput_gap",
         sf["mean_goodput"] - qf["mean_goodput"], note)
    note = f"{sf['steps_done']:.0f} vs {qf['steps_done']:.0f} steps"
    if sf["steps_done"] < qf["steps_done"]:
        note += " MISMATCH"
    emit("fleet/elastic_vs_queue_steps_ratio",
         sf["steps_done"] / max(qf["steps_done"], 1.0), note)
    ok = all(grammar_ok(j.ledger) for j in shrink.jobs.values())
    emit("fleet/elastic_grammar_ok", float(ok),
         "re-scale ledgers stay in the pinned 5-kind grammar"
         + ("" if ok else " MISMATCH"))


# ---------------------------------------------------------------------------
# Incremental deployment: cubes enter production as installed (paper §OCS).
# ---------------------------------------------------------------------------


def _emit_incremental(emit):
    waves = ((0.0, 16), (6 * _HOUR, 32), (12 * _HOUR, 48),
             (18 * _HOUR, 64))
    jobs = [JobSpec(name=f"inc{i}", chips=8 * 64, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=600)
            for i in range(8)]

    def deploy(schedule):
        cfg = FleetConfig(tpu="ironwood", total_cubes=64,
                          host_mtbf_hours=None,
                          install_schedule=schedule)
        sim = FleetSimulator(cfg, jobs)
        sim.run(1 * _DAY)
        waits = [j.first_admitted_at for j in sim.jobs.values()]
        return sim, waits

    def mean_wait_h(waits, horizon_s):
        # a never-admitted job waited at least the whole horizon
        return sum(horizon_s if w is None else w
                   for w in waits) / len(waits) / _HOUR

    sim, waits = deploy(waves)
    early = sum(1 for w in waits if w is not None and w < waves[-1][0])
    note = f"8x8-cube jobs, 64-cube pod installed over 18 h in 4 waves"
    if early < 6 or any(w is None for w in waits):
        note += " MISMATCH"
    emit("fleet/incremental_jobs_admitted_before_full_install", early, note)
    mean_wait_incr = mean_wait_h(waits, _DAY)
    # counterfactual: the whole pod lands at once at the 18 h mark
    _, waits_bulk = deploy(((18 * _HOUR, 64),))
    mean_wait_bulk = mean_wait_h(waits_bulk, _DAY)
    note = (f"incremental {mean_wait_incr:.1f} h vs wait-for-pod "
            f"{mean_wait_bulk:.1f} h mean admission wait")
    if mean_wait_incr >= mean_wait_bulk:
        note += " MISMATCH"
    emit("fleet/incremental_admission_wait_saved_h",
         mean_wait_bulk - mean_wait_incr, note)


# ---------------------------------------------------------------------------
# Slice size vs schedulability (paper: difficulty rises sharply w/o OCS).
# ---------------------------------------------------------------------------


def _emit_schedulability(emit):
    def fleet_goodput(size_cubes, contiguous):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=27,
                          host_mtbf_hours=None, contiguous=contiguous)
        jobs = [JobSpec(name=f"s{i}", chips=size_cubes * 64,
                        total_steps=10**9, step_time_s=1.0,
                        checkpoint_every_steps=600)
                for i in range(4)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(1 * _DAY)
        return sim.fleet_summary()["mean_goodput"]

    last_gap = None
    for size in (1, 4, 8):
        ocs_g = fleet_goodput(size, False)
        contig_g = fleet_goodput(size, True)
        note = (f"4 jobs x {size} cubes on a 27-cube (3x3x3) pod: "
                f"OCS {ocs_g:.2f} vs contiguous {contig_g:.2f}")
        if contig_g > ocs_g:
            note += " MISMATCH"
        emit(f"fleet/schedulability_{size}cube_gap", ocs_g - contig_g,
             note)
        last_gap = ocs_g - contig_g
    if last_gap is not None and last_gap <= 0:
        emit("fleet/schedulability_curve", 0.0,
             "largest slice must show an OCS advantage MISMATCH")


# ---------------------------------------------------------------------------
# Roofline-fed step times (per generation + the elastic scaling curve).
# ---------------------------------------------------------------------------


def _emit_roofline_steps(emit):
    times = generation_step_times(_WORKLOAD, cubes=8)
    names = [s.name for s in hwspec.GENERATIONS]
    vals = [times[n] for n in names]
    for n in names:
        emit(f"fleet/roofline_step_time_{n}", times[n],
             "70B dense, 16M-token batch, 8-cube slice")
    speedup = times["tpu_v2"] / times["ironwood"]
    ss = hwspec.scaling_summary()
    lo, hi = ss["hbm_bandwidth_x"], ss["node_peak_bf16_x"]
    note = (f"v2/Ironwood step-time ratio; Table-1 bounds "
            f"[{lo:.1f}x (HBM), {hi:.1f}x (peak bf16)]")
    if not (vals == sorted(vals, reverse=True)
            and lo <= speedup <= hi * 1.02):
        note += " MISMATCH"
    emit("fleet/roofline_step_speedup_v2_to_ironwood", speedup, note)

    model = StepTimeModel("tpu_v4", _WORKLOAD)
    sizes = (4, 8, 16, 32, 64, 128, 256)
    curve = {c: model(c) for c in sizes}
    halving = curve[128] / curve[256]
    note = (f"t(4..256 cubes)="
            + "|".join(f"{curve[c]:.1f}" for c in sizes)
            + "s — doubling 128->256 cubes buys "
            + f"{halving:.2f}x (<2x: the collective floor)")
    # non-increasing up to the ring factor: (n-1)/n nudges the
    # collective term up fractionally as the slice grows
    if not (all(curve[a] >= curve[b] * (1.0 - 1e-3)
                for a, b in zip(sizes, sizes[1:]))
            and halving < 1.5):
        note += " MISMATCH"
    emit("fleet/roofline_scaling_128_to_256_cubes", halving, note)

    spec = job_spec_from_roofline("probe", "tpu_v4", _WORKLOAD,
                                  chips=8 * 64, total_steps=1000,
                                  scale_policy="shrink", min_cubes=2)
    ok = abs(spec.step_time_s - model(8)) < 1e-9 \
        and spec.step_time_for(4) > spec.step_time_s
    emit("fleet/roofline_jobspec_consistent", float(ok),
         "JobSpec.step_time_s == model(full); shrink costs time"
         + ("" if ok else " MISMATCH"))


# ---------------------------------------------------------------------------
# Checkpoint-write contention + sim-vs-Young/Daly interval validation.
# ---------------------------------------------------------------------------


def _write_stalls(sim):
    return [e.seconds for j in sim.jobs.values()
            for e in j.ledger.events
            if e.kind == "idle" and e.note.startswith("ckpt write")]


def _emit_ckpt_contention(emit, *, smoke=False):
    def pod(arrival_offset_s):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=8,
                          host_mtbf_hours=None, ckpt_write_s=20.0)
        jobs = [JobSpec(name=f"w{i}", chips=2 * 64, total_steps=10**9,
                        step_time_s=1.0, checkpoint_every_steps=300,
                        arrival_s=i * arrival_offset_s)
                for i in range(4)]
        sim = FleetSimulator(cfg, jobs)
        sim.run(6 * _HOUR)
        return _write_stalls(sim)

    aligned, staggered = pod(0.0), pod(75.0)
    # shared-bandwidth stalls self-stagger aligned cadences after the
    # first collision (each writer resumes at a different time), so the
    # contention signal is the peak stall, not the steady-state mean
    peak_a, peak_s = max(aligned), max(staggered)
    note = (f"4 co-located jobs, shared filer: aligned-cadence peak "
            f"stall {peak_a:.0f} s vs staggered {peak_s:.0f} s "
            f"(uncontended 20 s; colliding cadences self-stagger)")
    if not peak_a > peak_s:
        note += " MISMATCH"
    emit("fleet/ckpt_contention_peak_stall_x", peak_a / peak_s, note)

    sweep = sim_checkpoint_interval_sweep(
        points=7 if smoke else 9, mean_failures=20 if smoke else 40)
    note = (f"sim optimum {sweep['sim_best_interval_s']:.0f} s vs model "
            f"{sweep['model_best_interval_s']:.0f} s "
            f"(grid bucket delta {sweep['bucket_delta']})")
    if not sweep["agree_within_one_bucket"]:
        note += " MISMATCH"
    emit("fleet/ckpt_interval_sim_vs_model_bucket_delta",
         sweep["bucket_delta"], note)


# ---------------------------------------------------------------------------
# Serve scenario suites: every benchmarks/scenarios/*.json runs as a row.
# ---------------------------------------------------------------------------


def _failed_checks(result):
    return "; ".join(
        f"{c['metric']} {c['op']} {c['target']} got {c['value']}"
        for c in result["checks"] if not c["ok"])


def _emit_scenarios(emit):
    paths = load_scenario_paths(SCENARIO_DIR)
    if not paths:
        emit("fleet/scenario_suite", 0.0,
             f"no scenario files under {SCENARIO_DIR} MISMATCH")
        return
    for path in paths:
        res = run_scenario(load_scenario(path))
        note = f"{len(res['checks'])} expect checks"
        if res["baseline_metrics"]:
            note += " + baseline arm"
        if not res["ok"]:
            note += f" MISMATCH: {_failed_checks(res)}"
        emit(f"fleet/scenario_{res['name']}", float(res["ok"]), note)
        for metric, value in sorted(res["metrics"].items()):
            job_metric = metric.split("/")[-1]
            if metric.startswith("serve/") and job_metric in (
                    "slo_goodput", "joules_per_token"):
                emit(f"fleet/scenario_{res['name']}:{metric}", value,
                     f"seeded arrivals, {res['metrics'].get('fleet/serve_finished', 0):.0f} requests served fleet-wide")


def _synthetic_serve_trace():
    """A measured-shape serve steptrace with a known affine batch law
    (base 20 ms + 2 ms/slot, 8-step chunks, 0.1 ms/prefill-token)."""
    tr = StepTrace(source="serve", meta={"synthetic": True})
    for rep in range(6):
        tr.record("prefill", 0.0128, tokens=128, cached=0, batch=1)
        for b in (1, 2, 3, 4):
            tr.record("decode", 0.020 + 0.002 * (b - 1),
                      batch=b, steps=8, tokens=b * 8, queue_depth=rep)
    return tr


# ---------------------------------------------------------------------------
# Suite entry (benchmarks/run.py) and the tier-1 smoke gate.
# ---------------------------------------------------------------------------


def run(emit) -> None:
    # -- Gemini 1.0 / TPU v4, single pod: 56-cube job + 8 spares ----------
    sim = _one_job_goodput("tpu_v4", total_cubes=64, chips=3584,
                           host_mtbf_hours=3600.0)
    g4 = sim.jobs["gem"].ledger.goodput
    note = "paper: ~0.97 (Gemini 1.0, TPU v4)"
    if not 0.955 <= g4 <= 0.985:
        note += " MISMATCH"
    emit("fleet/goodput_v4_single_pod", g4, note)
    emit("fleet/v4_failures", sim.stats["cube_failures"],
         f"{sim.sched.reconfig_count} OCS reconfigs, 0 starvations "
         f"expected={sim.stats['starvations'] == 0}")

    # -- Gemini 2.5 / TPU v5p, multi-pod: 2x140-cube pods + spares --------
    sim = _one_job_goodput("tpu_v5p", total_cubes=296, chips=280 * 64,
                           host_mtbf_hours=8000.0)
    g5 = sim.jobs["gem"].ledger.goodput
    note = "paper: ~0.93 (Gemini 2.5, multi-pod v5p)"
    if not 0.91 <= g5 <= 0.95:
        note += " MISMATCH"
    emit("fleet/goodput_v5p_multi_pod", g5, note)

    # -- Ironwood headline: four 2K jobs ride 16 spares through a week ----
    cfg = FleetConfig(tpu="ironwood", total_cubes=144,
                      host_mtbf_hours=2000.0,
                      sdc=SDCRateModel(rate_per_chip_hour=2e-6,
                                       screen_interval_s=600.0,
                                       screen_coverage=0.8),
                      seed=3)
    jobs = [JobSpec(name=f"job{i}", chips=2048, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=600)
            for i in range(4)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(7 * _DAY)
    fs = sim.fleet_summary()
    note = (f"{fs['cube_failures']:.0f} failures, "
            f"{fs['ocs_reconfigs']:.0f} reconfigs, "
            f"{fs['sdc_detections']:.0f} SDC rollbacks, "
            f"{fs['starvations']:.0f} starvations")
    if fs["starvations"] > 0 or fs["min_goodput"] < 0.9:
        note += " MISMATCH"
    emit("fleet/ironwood_4x2k_min_goodput", fs["min_goodput"], note)
    pm = PowerModel(hwspec.get("ironwood"))
    ps = pm.job_summary(sim.jobs["job0"].ledger, 2048)
    emit("fleet/ironwood_job_joules_per_eflop", ps["joules_per_eflop"],
         f"mfu={pm.mfu}, {ps['energy_kwh']:.0f} kWh over a week")
    emit("fleet/ironwood_job_gco2e_per_eflop", ps["gco2e_per_eflop"],
         "operational+embodied at market-based grid")

    # -- OCS vs pre-OCS contiguous scheduling, same failure trace ---------
    def flavor(contiguous):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=27,
                          host_mtbf_hours=300.0, repair_hours=2.0,
                          contiguous=contiguous, seed=5)
        js = [JobSpec(name=f"j{i}", chips=256, total_steps=10**9,
                      step_time_s=1.0, checkpoint_every_steps=300)
              for i in range(4)]
        s = FleetSimulator(cfg, js)
        s.run(2 * _DAY)
        return s.fleet_summary()["mean_goodput"]

    ocs_g, contig_g = flavor(False), flavor(True)
    note = "OCS spare substitution vs pre-OCS full reschedule"
    if ocs_g <= contig_g:
        note += " MISMATCH"
    emit("fleet/ocs_vs_contiguous_goodput_gap", ocs_g - contig_g, note)

    # -- sustainability: anchored-TDP chain vs the paper's ~29x -----------
    r = sustainability_ratios()
    note = f"paper perf/W row: {r['paper_perf_per_watt_x']:.1f}x"
    if abs(r["joules_per_flop_improvement_x"]
           - r["paper_perf_per_watt_x"]) / r["paper_perf_per_watt_x"] \
            > 0.02:
        note += " MISMATCH"
    emit("fleet/ironwood_vs_v2_joules_per_flop_x",
         r["joules_per_flop_improvement_x"], note)
    emit("fleet/ironwood_vs_v2_co2e_per_flop_x",
         r["co2e_per_flop_improvement_x"], "fixed-grid identity")

    # -- checkpoint-interval policy at the Gemini operating point ---------
    t_opt, g_opt = search_checkpoint_interval(
        mtbf_hours=6.0, detect_s=30.0, restore_s=120.0,
        checkpoint_write_s=10.0)
    emit("fleet/optimal_ckpt_interval_s", t_opt,
         f"goodput at optimum {g_opt:.4f} (async writes push this up)")

    # -- elastic scenario suite -------------------------------------------
    _emit_elastic(emit)
    _emit_incremental(emit)
    _emit_schedulability(emit)
    _emit_roofline_steps(emit)
    _emit_ckpt_contention(emit)

    # -- serve scenario suites + trace calibration ------------------------
    _emit_scenarios(emit)
    cal = serve_calibration_check(_synthetic_serve_trace())
    note = (f"sim {cal['sim_chunk_s'] * 1e3:.2f} ms vs measured "
            f"{cal['measured_chunk_s'] * 1e3:.2f} ms per chunk at batch "
            f"{cal['target_batch']:.0f} ({cal['steady_admissions']:.0f} "
            f"steady admissions)")
    if cal["ok"] != 1.0:
        note += " MISMATCH"
    emit("fleet/serve_calibration_rel_err", cal["rel_err"], note)

    # -- bridge: simulated ledger == measured ledger, event-for-event -----
    out = run_bridge(steps=18, checkpoint_every=6, failures={9: 0, 14: 1})
    note = (f"real goodput {out['real_goodput']:.3f}, "
            f"sim {out['sim_goodput']:.3f}")
    if not out["match"]:
        note += " MISMATCH"
    emit("fleet/bridge_structure_match", float(out["match"]), note)


def run_smoke() -> int:
    """Tier-1 fleet gate (seconds, deterministic, no jax): the re-scale
    arm must beat queue-only on goodput AND steps under the identical
    failure trace, stay inside the pinned ledger grammar, the
    sim-optimal checkpoint interval must agree with the closed-form
    search within one grid bucket — and the serve side must hold its
    gates: the autoscale-vs-static and burst-violation scenario suites
    pass their ``expect`` checks, a double-run of the mixed scenario is
    byte-identical (seeded open-loop arrivals), and the trace
    calibration round-trip recovers the synthetic service law."""
    failures = []

    def check(name, ok, detail):
        print(f"smoke [{name}]: {'ok' if ok else 'FAILED'} — {detail}")
        if not ok:
            failures.append(name)

    queue, shrink = _elastic_smoke_arm("queue"), _elastic_smoke_arm("shrink")
    qj, sj = queue.jobs["j0"], shrink.jobs["j0"]
    check("elastic-goodput", sj.ledger.goodput > qj.ledger.goodput,
          f"shrink {sj.ledger.goodput:.4f} > queue {qj.ledger.goodput:.4f}")
    check("elastic-steps", sj.base_step > qj.base_step,
          f"shrink {sj.base_step} > queue {qj.base_step} steps")
    check("elastic-lifecycle",
          sj.rescales == 1 and sj.grow_backs == 1
          and queue.stats["starvations"] == 1,
          f"{sj.rescales} re-scale + {sj.grow_backs} grow-back vs "
          f"{queue.stats['starvations']} starvation")
    check("elastic-grammar",
          all(grammar_ok(j.ledger) for j in shrink.jobs.values()),
          "ledger kinds within the pinned 5-kind grammar")
    sweep = sim_checkpoint_interval_sweep(points=7, mean_failures=20)
    check("ckpt-interval-agreement", sweep["agree_within_one_bucket"],
          f"sim {sweep['sim_best_interval_s']:.0f} s vs model "
          f"{sweep['model_best_interval_s']:.0f} s "
          f"(bucket delta {sweep['bucket_delta']})")

    # -- serve gates ------------------------------------------------------
    for fname in ("serve_autoscale_vs_static.json",
                  "serve_burst_slo_violation.json"):
        res = run_scenario(load_scenario(SCENARIO_DIR / fname))
        detail = f"{len(res['checks'])} expect checks pass"
        if not res["ok"]:
            detail = _failed_checks(res)
        check(f"serve-{res['name']}", res["ok"], detail)
    doc = load_scenario(SCENARIO_DIR / "serve_burst_slo_violation.json")
    runs = [json.dumps(run_scenario(doc)["metrics"], sort_keys=True)
            for _ in range(2)]
    check("serve-determinism", runs[0] == runs[1],
          f"double-run metrics byte-identical ({len(runs[0])} bytes)")
    cal = serve_calibration_check(_synthetic_serve_trace())
    check("serve-calibration", cal["ok"] == 1.0,
          f"rel_err {cal['rel_err']:.2e} over "
          f"{cal['steady_admissions']:.0f} steady admissions at batch "
          f"{cal['target_batch']:.0f}")
    print("bench_fleet --smoke:", "FAILED" if failures else "PASSED")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser(
        description="fleet suite (standalone); see docs/benchmarks.md")
    ap.add_argument("--smoke", action="store_true",
                    help="fast deterministic elastic + ckpt-interval gate "
                         "(tier-1)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(run_smoke())

    def emit(name, value, note=""):
        val = f"{value:.6g}" if isinstance(value, float) else str(value)
        print(f"{name},{val},{note}", flush=True)

    print("name,value,note")
    run(emit)


if __name__ == "__main__":
    main()
