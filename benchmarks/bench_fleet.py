"""§Resilience + §Sustainability at fleet scale: the discrete-event
simulator reproducing the paper's goodput anchors (Gemini 1.0 on TPU v4
~97%; Gemini 2.5 multi-pod on TPU v5p ~93%), the Ironwood 4x2K-job
spare-cube scenario, the OCS-vs-contiguous resilience gap, the
Ironwood-vs-v2 sustainability ratio from the anchored TDP chain, and the
sim-vs-ResilientTrainer bridge."""

from repro.core.sdc import SDCRateModel
from repro.fleet import (FleetConfig, FleetSimulator, JobSpec, PowerModel,
                         run_bridge, search_checkpoint_interval,
                         sustainability_ratios)
from repro.core import hwspec

_DAY = 86400.0


def _one_job_goodput(tpu, total_cubes, chips, host_mtbf_hours, days=4.0,
                     seed=1):
    cfg = FleetConfig(tpu=tpu, total_cubes=total_cubes,
                      host_mtbf_hours=host_mtbf_hours, seed=seed)
    # 2 s steps, snapshot every 300 steps = the paper-era 10-minute cadence
    job = JobSpec(name="gem", chips=chips, total_steps=10**9,
                  step_time_s=2.0, checkpoint_every_steps=300)
    sim = FleetSimulator(cfg, [job])
    sim.run(days * _DAY)
    return sim


def run(emit) -> None:
    # -- Gemini 1.0 / TPU v4, single pod: 56-cube job + 8 spares ----------
    sim = _one_job_goodput("tpu_v4", total_cubes=64, chips=3584,
                           host_mtbf_hours=3600.0)
    g4 = sim.jobs["gem"].ledger.goodput
    note = "paper: ~0.97 (Gemini 1.0, TPU v4)"
    if not 0.955 <= g4 <= 0.985:
        note += " MISMATCH"
    emit("fleet/goodput_v4_single_pod", g4, note)
    emit("fleet/v4_failures", sim.stats["cube_failures"],
         f"{sim.sched.reconfig_count} OCS reconfigs, 0 starvations "
         f"expected={sim.stats['starvations'] == 0}")

    # -- Gemini 2.5 / TPU v5p, multi-pod: 2x140-cube pods + spares --------
    sim = _one_job_goodput("tpu_v5p", total_cubes=296, chips=280 * 64,
                           host_mtbf_hours=8000.0)
    g5 = sim.jobs["gem"].ledger.goodput
    note = "paper: ~0.93 (Gemini 2.5, multi-pod v5p)"
    if not 0.91 <= g5 <= 0.95:
        note += " MISMATCH"
    emit("fleet/goodput_v5p_multi_pod", g5, note)

    # -- Ironwood headline: four 2K jobs ride 16 spares through a week ----
    cfg = FleetConfig(tpu="ironwood", total_cubes=144,
                      host_mtbf_hours=2000.0,
                      sdc=SDCRateModel(rate_per_chip_hour=2e-6,
                                       screen_interval_s=600.0,
                                       screen_coverage=0.8),
                      seed=3)
    jobs = [JobSpec(name=f"job{i}", chips=2048, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=600)
            for i in range(4)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(7 * _DAY)
    fs = sim.fleet_summary()
    note = (f"{fs['cube_failures']:.0f} failures, "
            f"{fs['ocs_reconfigs']:.0f} reconfigs, "
            f"{fs['sdc_detections']:.0f} SDC rollbacks, "
            f"{fs['starvations']:.0f} starvations")
    if fs["starvations"] > 0 or fs["min_goodput"] < 0.9:
        note += " MISMATCH"
    emit("fleet/ironwood_4x2k_min_goodput", fs["min_goodput"], note)
    pm = PowerModel(hwspec.get("ironwood"))
    ps = pm.job_summary(sim.jobs["job0"].ledger, 2048)
    emit("fleet/ironwood_job_joules_per_eflop", ps["joules_per_eflop"],
         f"mfu={pm.mfu}, {ps['energy_kwh']:.0f} kWh over a week")
    emit("fleet/ironwood_job_gco2e_per_eflop", ps["gco2e_per_eflop"],
         "operational+embodied at market-based grid")

    # -- OCS vs pre-OCS contiguous scheduling, same failure trace ---------
    def flavor(contiguous):
        cfg = FleetConfig(tpu="tpu_v4", total_cubes=27,
                          host_mtbf_hours=300.0, repair_hours=2.0,
                          contiguous=contiguous, seed=5)
        js = [JobSpec(name=f"j{i}", chips=256, total_steps=10**9,
                      step_time_s=1.0, checkpoint_every_steps=300)
              for i in range(4)]
        s = FleetSimulator(cfg, js)
        s.run(2 * _DAY)
        return s.fleet_summary()["mean_goodput"]

    ocs_g, contig_g = flavor(False), flavor(True)
    note = "OCS spare substitution vs pre-OCS full reschedule"
    if ocs_g <= contig_g:
        note += " MISMATCH"
    emit("fleet/ocs_vs_contiguous_goodput_gap", ocs_g - contig_g, note)

    # -- sustainability: anchored-TDP chain vs the paper's ~29x -----------
    r = sustainability_ratios()
    note = f"paper perf/W row: {r['paper_perf_per_watt_x']:.1f}x"
    if abs(r["joules_per_flop_improvement_x"]
           - r["paper_perf_per_watt_x"]) / r["paper_perf_per_watt_x"] \
            > 0.02:
        note += " MISMATCH"
    emit("fleet/ironwood_vs_v2_joules_per_flop_x",
         r["joules_per_flop_improvement_x"], note)
    emit("fleet/ironwood_vs_v2_co2e_per_flop_x",
         r["co2e_per_flop_improvement_x"], "fixed-grid identity")

    # -- checkpoint-interval policy at the Gemini operating point ---------
    t_opt, g_opt = search_checkpoint_interval(
        mtbf_hours=6.0, detect_s=30.0, restore_s=120.0,
        checkpoint_write_s=10.0)
    emit("fleet/optimal_ckpt_interval_s", t_opt,
         f"goodput at optimum {g_opt:.4f} (async writes push this up)")

    # -- bridge: simulated ledger == measured ledger, event-for-event -----
    out = run_bridge(steps=18, checkpoint_every=6, failures={9: 0, 14: 1})
    note = (f"real goodput {out['real_goodput']:.3f}, "
            f"sim {out['sim_goodput']:.3f}")
    if not out["match"]:
        note += " MISMATCH"
    emit("fleet/bridge_structure_match", float(out["match"]), note)
