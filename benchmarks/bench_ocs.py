"""§Resilience / Figure 4: OCS scheduling, spare cubes, availability.

Reproduces: (a) cube/OCS arithmetic (96 optical links per cube, 48 OCSes,
64 cubes -> 4096 chips); (b) "Ironwood can run four 2K-slice jobs even with
failed nodes, as 16 spare cubes remain"; (c) scheduling success with vs
without OCS (contiguity) under load; (d) host-availability -> slice
availability ("without OCSes, host availability must be >99.9%")."""

import numpy as np

from repro.core import hwspec
from repro.core.ocs import (CUBE, OCSPodScheduler, monte_carlo_contiguous_vs_ocs,
                            schedulable_jobs, slice_availability)


def run(emit) -> None:
    emit("ocs/optical_links_per_cube", CUBE.optical_links, "paper=96")
    emit("ocs/ocses_per_cube", CUBE.ocses_per_cube, "paper=48")
    emit("ocs/tpuv4_chips", 64 * CUBE.chips, "paper=4096")

    # Ironwood: 9216 chips = 144 cubes; four 2048-chip jobs = 128 cubes
    total_cubes = hwspec.IRONWOOD.pod_size // CUBE.chips
    emit("ocs/ironwood_cubes", total_cubes, "9216/64")
    sched = OCSPodScheduler(total_cubes)
    for j in range(4):
        alloc = sched.allocate(f"job{j}", 2048)
        assert alloc is not None
    emit("ocs/spare_cubes_after_4x2k", sched.spare_cubes(), "paper=16")
    # kill a cube inside each job; all four must substitute successfully
    ok = 0
    for j in range(4):
        victim = sched.allocations[f"job{j}"].cubes[0]
        assert sched.fail_cube(victim) == f"job{j}"
        if sched.substitute(f"job{j}") is not None:
            ok += 1
    emit("ocs/jobs_surviving_1_failure_each", ok, "expect 4")
    emit("ocs/max_schedulable_2k_jobs_12_failed",
         schedulable_jobs(total_cubes, 12, 2048), "expect 4")

    # contiguity penalty: P(success) for a 32-cube job at 50% busy
    mc = monte_carlo_contiguous_vs_ocs(64, 8, 0.5, trials=60, seed=7)
    emit("ocs/p_sched_ocs_8cubes_50pct", mc["p_success_ocs"], "")
    emit("ocs/p_sched_contig_8cubes_50pct", mc["p_success_contiguous"],
         "contiguous << OCS (paper: scheduling difficulty rises sharply)")

    # host availability: Ironwood has 2304 hosts
    hosts = hwspec.IRONWOOD.hosts_per_pod
    emit("ocs/ironwood_hosts", hosts, "paper=2304")
    for a in (0.999, 0.9999):
        emit(f"ocs/pod_avail_host_{a}", slice_availability(a, 9216),
             "paper: host avail must be >99.9% without OCS isolation")
    # with OCS, the unit of failure is a 64-chip cube slice (16 hosts)
    emit("ocs/slice2k_avail_host_0.999", slice_availability(0.999, 2048),
         "2k slice, 512 hosts")
