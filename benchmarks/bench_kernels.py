"""MXU/VPU kernel microbenchmarks: wall time of the jnp reference path on
CPU (interpret-mode Pallas timing is not meaningful) + analytic MXU cycle
counts for the kernels' BlockSpecs on the v5e target."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hwspec import ROOFLINE_TARGET, TPU_V5E
from repro.kernels import ops


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(emit) -> None:
    key = jax.random.key(0)
    m = k = n = 512
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(key, (k, n), jnp.float32)
    us = _time(lambda x, y: ops.matmul(x, y, impl="ref"), a, b)
    flops = 2 * m * k * n
    emit("kernels/matmul_512_ref_us", us,
         f"{flops / (us * 1e-6) / 1e9:.1f} GFLOP/s host")
    # v5e MXU bound: 4x 128x128 MXUs; cycles = flops / (2*4*128*128)
    mxu_cycles = flops / TPU_V5E.matmul_peak_flops_per_cycle("bf16")
    emit("kernels/matmul_512_v5e_mxu_cycles", mxu_cycles,
         f"={flops / ROOFLINE_TARGET.peak_flops * 1e6:.2f}us at peak")

    q = jax.random.normal(key, (8, 1024, 64), jnp.float32)
    us = _time(lambda x: ops.flash_attention(x, x, x, impl="ref"), q)
    emit("kernels/flash_attn_8x1024x64_ref_us", us, "")

    kc = jax.random.normal(key, (4, 4096, 8, 64), jnp.float32)
    qd = jax.random.normal(key, (4, 32, 64), jnp.float32)
    pos = jnp.full((4,), 4096, jnp.int32)
    us = _time(lambda *xs: ops.decode_attention(*xs, impl="ref"),
               qd, kc, kc, pos)
    cache_bytes = 2 * kc.size * 2  # bf16 on TPU
    emit("kernels/decode_attn_4x4096_ref_us", us,
         f"v5e HBM-bound={cache_bytes / ROOFLINE_TARGET.hbm_bw * 1e6:.1f}us")

    r = jax.random.normal(key, (8, 512, 64), jnp.float32)
    lw = jnp.clip(-jnp.exp(jax.random.normal(key, (8, 512, 64))), -4., 0.)
    u = jax.random.normal(key, (8, 64)) * 0.5
    us = _time(lambda *xs: ops.rwkv_wkv(*xs, impl="ref"), r, r, r, lw, u)
    emit("kernels/rwkv_wkv_8x512x64_ref_us", us, "chunked oracle")

    tbl = jax.random.normal(key, (65536, 128), jnp.float32)
    idx = jax.random.randint(key, (1024, 8), 0, 65536)
    w = jax.random.normal(key, (1024, 8), jnp.float32)
    us = _time(lambda *xs: ops.sparse_gather_sum(*xs, impl="ref"),
               tbl, idx, w)
    gathered = 1024 * 8 * 128 * 4
    emit("kernels/sparse_gather_1kx8_ref_us", us,
         f"v5e HBM-bound={gathered / ROOFLINE_TARGET.hbm_bw * 1e6:.2f}us")

    # m-grouped MoE GEMM: 2048 sorted rows over 16 experts, block_m=128.
    # Weight traffic = one (D, F) tile per m-tile (vs all-E for a dense
    # capacity buffer); the v5e bound is that stream at HBM rate.
    mg, dg, fg, eg = 2048, 512, 1024, 16
    xg = jax.random.normal(key, (mg, dg), jnp.float32)
    wg = jax.random.normal(key, (eg, dg, fg), jnp.float32)
    gids = jnp.repeat(jnp.arange(16, dtype=jnp.int32), 1)
    us = _time(lambda *xs: ops.grouped_matmul(*xs, impl="ref"),
               xg, wg, gids)
    wbytes = gids.shape[0] * dg * fg * 2  # one bf16 tile per m-tile
    emit("kernels/moe_grouped_2048x512x1024_ref_us", us,
         f"v5e weight-stream bound="
         f"{wbytes / ROOFLINE_TARGET.hbm_bw * 1e6:.1f}us")
