"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,note`` CSV; ``--json`` additionally writes one
machine-readable ``BENCH_<suite>.json`` per suite run (e.g.
``BENCH_serve.json`` / ``BENCH_kernels.json``) so a trajectory can be
tracked across commits. Each JSON document is
``{"suite", "rows", "metrics"}``: the emitted rows plus the suite's
final telemetry-registry snapshot (``metrics_snapshot()`` hook on the
suite module; ``{}`` for suites without one). Usage:
  PYTHONPATH=src python -m benchmarks.run [--only table1,serve,...] [--json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks import (bench_cci, bench_fleet, bench_goodput,
                        bench_kernels, bench_ocs, bench_perf_watt,
                        bench_roofline, bench_sdc, bench_serve,
                        bench_table1)

SUITES = {
    "table1": bench_table1,
    "fig5_perf_watt": bench_perf_watt,
    "fig6_cci": bench_cci,
    "ocs": bench_ocs,
    "goodput": bench_goodput,
    "fleet": bench_fleet,
    "sdc": bench_sdc,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "serve": bench_serve,
}


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog="Per-suite paper anchors and expected output shapes are "
               "documented in docs/benchmarks.md.")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<suite>.json per suite run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(SUITES)
        if unknown:
            ap.error(f"unknown suites {sorted(unknown)}; "
                     f"have {sorted(SUITES)}")

    failures = []
    rows: list = []

    def emit(name: str, value, note: str = "") -> None:
        if isinstance(value, float):
            val = f"{value:.6g}"
        else:
            val = str(value)
        print(f"{name},{val},{note}", flush=True)
        rows.append({"name": name, "value": value, "note": note})
        if "MISMATCH" in note or "FAILED" in note:
            failures.append(name)

    print("name,value,note")
    for name, mod in SUITES.items():
        if only and name not in only:
            continue
        rows = []
        t0 = time.time()
        mod.run(emit)
        emit(f"{name}/_suite_seconds", time.time() - t0, "")
        if args.json:
            # suites expose metrics_snapshot() to embed their final
            # telemetry-registry state alongside the rows
            snap_fn = getattr(mod, "metrics_snapshot", None)
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump({"suite": name, "rows": rows,
                           "metrics": snap_fn() if snap_fn else {}},
                          f, indent=1, default=str)
            print(f"# wrote {path}", flush=True)
    if failures:
        print(f"\n{len(failures)} MISMATCH/FAILED rows: {failures[:10]}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
