"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,note`` CSV. Usage:
  PYTHONPATH=src python -m benchmarks.run [--only table1,fig5,...]
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_cci, bench_fleet, bench_goodput,
                        bench_kernels, bench_ocs, bench_perf_watt,
                        bench_roofline, bench_sdc, bench_table1)

SUITES = {
    "table1": bench_table1,
    "fig5_perf_watt": bench_perf_watt,
    "fig6_cci": bench_cci,
    "ocs": bench_ocs,
    "goodput": bench_goodput,
    "fleet": bench_fleet,
    "sdc": bench_sdc,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog="Per-suite paper anchors and expected output shapes are "
               "documented in docs/benchmarks.md.")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []

    def emit(name: str, value, note: str = "") -> None:
        if isinstance(value, float):
            val = f"{value:.6g}"
        else:
            val = str(value)
        print(f"{name},{val},{note}", flush=True)
        if "MISMATCH" in note or "FAILED" in note:
            failures.append(name)

    print("name,value,note")
    for name, mod in SUITES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        mod.run(emit)
        emit(f"{name}/_suite_seconds", time.time() - t0, "")
    if failures:
        print(f"\n{len(failures)} MISMATCH/FAILED rows: {failures[:10]}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
