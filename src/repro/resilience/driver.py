"""Resilient training driver: failure injection, OCS map-out, elastic
re-mesh, straggler mitigation, goodput accounting.

This is the paper's §Resilience as an executable loop:

  detect (health checks / SDC screens / injected faults)
    -> map out the failed cube via the OCS scheduler (spare substitution)
    -> restore from the last checkpoint (elastic: the new slice may be
       smaller or larger; arrays re-shard on load)
    -> replay the deterministic pipeline from the restored step
    -> goodput ledger charges detection + restore + rework.

On this CPU container the "cluster" is simulated (FailurePlan injects
failures at chosen steps; step time is measured wall time), but every state
transition — checkpoint, scheduler substitution, re-mesh, replay — is the
real code path the framework would run on a pod.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.goodput import GoodputLedger
from repro.core.ocs import OCSPodScheduler
from repro.data.pipeline import DataPipeline

PyTree = Any


@dataclasses.dataclass
class FailurePlan:
    """Deterministic injected failures: step -> cube id that dies there."""

    failures: Dict[int, int] = dataclasses.field(default_factory=dict)
    detect_s: float = 0.05
    restore_extra_s: float = 0.05

    def failure_at(self, step: int) -> Optional[int]:
        return self.failures.get(step)


@dataclasses.dataclass
class StragglerPolicy:
    """Detect slow steps; after ``patience`` consecutive slow steps the
    driver reports the node for map-out (the paper's modular-isolation
    response to gray failures)."""

    threshold: float = 3.0  # x median step time
    patience: int = 3

    def __post_init__(self) -> None:
        self._times: List[float] = []
        self._slow = 0

    def observe(self, dt: float) -> bool:
        self._times.append(dt)
        if len(self._times) < 8:
            return False
        median = float(np.median(self._times[-50:]))
        if dt > self.threshold * median:
            self._slow += 1
        else:
            self._slow = 0
        return self._slow >= self.patience


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One train_step execution, replayed or not.

    ``losses`` (the per-step effective trace) intentionally excludes
    replays so an interrupted run compares 1:1 against an uninterrupted
    one; ``records`` keeps every execution with its provenance for the
    goodput/rework post-mortem."""

    step: int
    loss: float
    replayed: bool = False
    duration_s: float = 0.0


@dataclasses.dataclass
class ResilientTrainer:
    train_step: Callable[[PyTree, Dict[str, Any]], Tuple[PyTree, Dict]]
    pipeline: DataPipeline
    ckpt: CheckpointManager
    scheduler: OCSPodScheduler
    job: str
    checkpoint_every: int = 20
    failure_plan: FailurePlan = dataclasses.field(default_factory=FailurePlan)
    straggler: StragglerPolicy = dataclasses.field(
        default_factory=StragglerPolicy)

    def run(self, state: PyTree, num_steps: int,
            ledger: Optional[GoodputLedger] = None
            ) -> Tuple[PyTree, GoodputLedger, List[float]]:
        ledger = ledger or GoodputLedger()
        losses: List[float] = []
        self.records: List[StepRecord] = []
        step = int(jax.device_get(state["step"]))
        last_ckpt_step = self.ckpt.latest_step()
        if last_ckpt_step is None:
            # Bootstrap: the resilience contract says recovery always
            # restores from a checkpoint. Before the first periodic
            # snapshot exists, a failure would otherwise have nothing to
            # restore — write the starting state synchronously.
            t0 = time.monotonic()
            self.ckpt.save(step, state, blocking=True)
            ledger.record_idle(time.monotonic() - t0,
                               note="bootstrap ckpt")
            last_ckpt_step = step
        while step < num_steps:
            cube = self.failure_plan.failure_at(step)
            if cube is not None:
                # ---- failure path: detect -> map out -> restore -> replay
                ledger.record_detection(self.failure_plan.detect_s,
                                        note=f"cube {cube} died")
                impacted = self.scheduler.fail_cube(cube)
                patched = self.scheduler.substitute(self.job) \
                    if impacted == self.job else None
                if impacted == self.job and patched is None:
                    raise RuntimeError(
                        "no spare cubes: job cannot continue")
                t0 = time.monotonic()
                # Flush any in-flight async snapshot BEFORE asking what the
                # latest checkpoint is: querying first races the writer
                # thread, and losing that race silently "replays" from an
                # older step than the state actually holds.
                self.ckpt.wait()
                restore_step = self.ckpt.latest_step()
                assert restore_step is not None  # bootstrap guarantees one
                state = self.ckpt.restore(restore_step, state)
                last_ckpt_step = restore_step
                ledger.record_restore(
                    time.monotonic() - t0 + self.failure_plan.restore_extra_s)
                # rework: re-run steps since the checkpoint
                t0 = time.monotonic()
                for replay in range(restore_step, step):
                    batch = self.pipeline.batch_for_step(replay)
                    state, metrics = self.train_step(state, batch)
                    self.records.append(StepRecord(
                        step=replay,
                        loss=float(jax.device_get(metrics["loss"])),
                        replayed=True))
                ledger.record_rework(time.monotonic() - t0,
                                     steps=step - restore_step)
                # the failure is handled; do not re-trigger
                del self.failure_plan.failures[step]
                continue

            batch = self.pipeline.batch_for_step(step)
            t0 = time.monotonic()
            state, metrics = self.train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.monotonic() - t0
            ledger.record_steps(dt, steps=1)
            losses.append(loss)
            self.records.append(StepRecord(step=step, loss=loss,
                                           duration_s=dt))
            if self.straggler.observe(dt):
                ledger.record_idle(0.0, note="straggler flagged for map-out")
            step += 1
            if step % self.checkpoint_every == 0:
                state = jax.block_until_ready(state)
                t0 = time.monotonic()
                self.ckpt.save(step, state)  # async
                ledger.record_idle(time.monotonic() - t0,
                                   note="ckpt snapshot")
                last_ckpt_step = step
        self.ckpt.wait()
        return state, ledger, losses

    def replay_summary(self) -> Dict[str, int]:
        """Execution counts from the StepRecord ledger: how many
        train_step calls ran in total, how many were replays (rework
        after a restore), and the effective (non-replayed) count.

        ``rescales`` keeps the key set aligned with the fleet
        simulator's elastic ledger (``FleetSimulator.fleet_summary``):
        the real trainer always restores at full scale — OCS spare
        substitution, never a smaller slice — so it is constitutionally
        zero here, and nonzero only in the sim's elastic arm."""
        recs = getattr(self, "records", [])
        replayed = sum(1 for r in recs if r.replayed)
        return {
            "executions": len(recs),
            "replayed_steps": replayed,
            "effective_steps": len(recs) - replayed,
            "rescales": 0,
        }
