"""Resilient training driver: failure injection, OCS map-out, elastic
re-mesh, straggler mitigation, goodput accounting.

This is the paper's §Resilience as an executable loop:

  detect (health checks / SDC screens / injected faults)
    -> map out the failed cube via the OCS scheduler (spare substitution)
    -> restore from the last checkpoint (elastic: the new slice may be
       smaller or larger; arrays re-shard on load)
    -> replay the deterministic pipeline from the restored step
    -> goodput ledger charges detection + restore + rework.

On this CPU container the "cluster" is simulated (FailurePlan injects
failures at chosen steps; step time is measured wall time), but every state
transition — checkpoint, scheduler substitution, re-mesh, replay — is the
real code path the framework would run on a pod.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.goodput import GoodputLedger
from repro.core.ocs import OCSPodScheduler
from repro.data.pipeline import DataPipeline
from repro.obs.metrics import MetricsRegistry
from repro.obs.steptrace import StepTrace
from repro.obs.trace import SpanTracer

PyTree = Any


@dataclasses.dataclass
class FailurePlan:
    """Deterministic injected failures: step -> cube id that dies there."""

    failures: Dict[int, int] = dataclasses.field(default_factory=dict)
    detect_s: float = 0.05
    restore_extra_s: float = 0.05

    def failure_at(self, step: int) -> Optional[int]:
        return self.failures.get(step)


@dataclasses.dataclass
class StragglerPolicy:
    """Detect slow steps; after ``patience`` consecutive slow steps the
    driver reports the node for map-out (the paper's modular-isolation
    response to gray failures)."""

    threshold: float = 3.0  # x median step time
    patience: int = 3

    def __post_init__(self) -> None:
        self._times: List[float] = []
        self._slow = 0

    def observe(self, dt: float) -> bool:
        self._times.append(dt)
        if len(self._times) < 8:
            return False
        median = float(np.median(self._times[-50:]))
        if dt > self.threshold * median:
            self._slow += 1
        else:
            self._slow = 0
        return self._slow >= self.patience


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One train_step execution, replayed or not.

    ``losses`` (the per-step effective trace) intentionally excludes
    replays so an interrupted run compares 1:1 against an uninterrupted
    one; ``records`` keeps every execution with its provenance for the
    goodput/rework post-mortem."""

    step: int
    loss: float
    replayed: bool = False
    duration_s: float = 0.0


@dataclasses.dataclass
class ResilientTrainer:
    train_step: Callable[[PyTree, Dict[str, Any]], Tuple[PyTree, Dict]]
    pipeline: DataPipeline
    ckpt: CheckpointManager
    scheduler: OCSPodScheduler
    job: str
    checkpoint_every: int = 20
    failure_plan: FailurePlan = dataclasses.field(default_factory=FailurePlan)
    straggler: StragglerPolicy = dataclasses.field(
        default_factory=StragglerPolicy)
    metrics: Optional[MetricsRegistry] = None  # None -> fresh enabled one
    tracer: Optional[SpanTracer] = None  # None -> disabled

    def __post_init__(self) -> None:
        # Telemetry is host-side: counters/spans around the (unchanged)
        # train_step calls, same phase names as the fleet sim's trace
        # ("train"/"rework"/"restore"/"detect"/"ckpt") so both render
        # alike in one timeline.
        if self.metrics is None:
            self.metrics = MetricsRegistry()
        if self.tracer is None:
            self.tracer = SpanTracer(enabled=False)
        m = self.metrics
        self._m = {
            "steps": m.counter("train_steps"),
            "replayed": m.counter("train_replayed_steps"),
            "ckpts": m.counter("train_ckpt_saves"),
            "failures": m.counter("train_failures"),
            "restores": m.counter("train_restores"),
            "step_hist": m.histogram("train_step_s"),
        }
        self._trace_pid = self.tracer.process("train")

    def run(self, state: PyTree, num_steps: int,
            ledger: Optional[GoodputLedger] = None
            ) -> Tuple[PyTree, GoodputLedger, List[float]]:
        ledger = ledger or GoodputLedger()
        losses: List[float] = []
        self.records: List[StepRecord] = []
        step = int(jax.device_get(state["step"]))
        last_ckpt_step = self.ckpt.latest_step()
        if last_ckpt_step is None:
            # Bootstrap: the resilience contract says recovery always
            # restores from a checkpoint. Before the first periodic
            # snapshot exists, a failure would otherwise have nothing to
            # restore — write the starting state synchronously.
            t0 = time.monotonic()
            self.ckpt.save(step, state, blocking=True)
            dt = time.monotonic() - t0
            ledger.record_idle(dt, note="bootstrap ckpt")
            self._m["ckpts"].inc()
            self.tracer.complete("ckpt", dt, pid=self._trace_pid,
                                 tid=0, cat="train",
                                 args={"step": step, "bootstrap": True})
            last_ckpt_step = step
        while step < num_steps:
            cube = self.failure_plan.failure_at(step)
            if cube is not None:
                # ---- failure path: detect -> map out -> restore -> replay
                self._m["failures"].inc()
                self.tracer.instant("cube_fail", pid=self._trace_pid,
                                    tid=0, cat="train",
                                    args={"cube": cube, "step": step})
                ledger.record_detection(self.failure_plan.detect_s,
                                        note=f"cube {cube} died")
                self.tracer.complete("detect", self.failure_plan.detect_s,
                                     pid=self._trace_pid, tid=0,
                                     cat="train", args={"cube": cube})
                impacted = self.scheduler.fail_cube(cube)
                patched = self.scheduler.substitute(self.job) \
                    if impacted == self.job else None
                if impacted == self.job and patched is None:
                    raise RuntimeError(
                        "no spare cubes: job cannot continue")
                t0 = time.monotonic()
                # Flush any in-flight async snapshot BEFORE asking what the
                # latest checkpoint is: querying first races the writer
                # thread, and losing that race silently "replays" from an
                # older step than the state actually holds.
                self.ckpt.wait()
                restore_step = self.ckpt.latest_step()
                assert restore_step is not None  # bootstrap guarantees one
                state = self.ckpt.restore(restore_step, state)
                # restore() may have quarantined a corrupt checkpoint and
                # fallen back to an older one — re-anchor the replay range
                # on the step actually loaded or it silently starts late.
                restore_step = self.ckpt.last_restored_step
                last_ckpt_step = restore_step
                restore_dt = (time.monotonic() - t0
                              + self.failure_plan.restore_extra_s)
                ledger.record_restore(restore_dt)
                self._m["restores"].inc()
                self.tracer.complete("restore", restore_dt,
                                     pid=self._trace_pid, tid=0,
                                     cat="train",
                                     args={"from_step": restore_step})
                # rework: re-run steps since the checkpoint
                t0 = time.monotonic()
                for replay in range(restore_step, step):
                    batch = self.pipeline.batch_for_step(replay)
                    t1 = time.monotonic()
                    state, metrics = self.train_step(state, batch)
                    loss_r = float(jax.device_get(metrics["loss"]))
                    dt_r = time.monotonic() - t1
                    self._m["replayed"].inc()
                    self.tracer.complete("replay", dt_r,
                                         pid=self._trace_pid, tid=0,
                                         cat="train",
                                         args={"step": replay})
                    self.records.append(StepRecord(
                        step=replay, loss=loss_r, replayed=True,
                        duration_s=dt_r))
                ledger.record_rework(time.monotonic() - t0,
                                     steps=step - restore_step)
                # the failure is handled; do not re-trigger
                del self.failure_plan.failures[step]
                continue

            batch = self.pipeline.batch_for_step(step)
            t0 = time.monotonic()
            state, metrics = self.train_step(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.monotonic() - t0
            ledger.record_steps(dt, steps=1)
            losses.append(loss)
            self._m["steps"].inc()
            self._m["step_hist"].observe(dt)
            self.tracer.complete("step", dt, pid=self._trace_pid, tid=0,
                                 cat="train", args={"step": step})
            self.records.append(StepRecord(step=step, loss=loss,
                                           duration_s=dt))
            if self.straggler.observe(dt):
                ledger.record_idle(0.0, note="straggler flagged for map-out")
            step += 1
            if step % self.checkpoint_every == 0:
                state = jax.block_until_ready(state)
                t0 = time.monotonic()
                self.ckpt.save(step, state)  # async
                dt = time.monotonic() - t0
                ledger.record_idle(dt, note="ckpt snapshot")
                self._m["ckpts"].inc()
                self.tracer.complete("ckpt", dt, pid=self._trace_pid,
                                     tid=0, cat="train",
                                     args={"step": step})
                last_ckpt_step = step
        self.ckpt.wait()
        return state, ledger, losses

    def replay_summary(self) -> Dict[str, int]:
        """Execution counts from the StepRecord ledger: how many
        train_step calls ran in total, how many were replays (rework
        after a restore), and the effective (non-replayed) count.

        ``rescales`` keeps the key set aligned with the fleet
        simulator's elastic ledger (``FleetSimulator.fleet_summary``):
        the real trainer always restores at full scale — OCS spare
        substitution, never a smaller slice — so it is constitutionally
        zero here, and nonzero only in the sim's elastic arm."""
        recs = getattr(self, "records", [])
        replayed = sum(1 for r in recs if r.replayed)
        return {
            "executions": len(recs),
            "replayed_steps": replayed,
            "effective_steps": len(recs) - replayed,
            "rescales": 0,
        }

    def steptrace(self) -> StepTrace:
        """The run's measured step-time trace: one "step" event per
        effective execution, one "replay" per rework execution, with
        wall durations — the artifact
        ``fleet.perf.StepTimeModel.from_trace`` replays through the
        simulator."""
        tr = StepTrace(source="train", meta={"job": self.job})
        for r in getattr(self, "records", []):
            tr.record("replay" if r.replayed else "step",
                      r.duration_s, step=r.step)
        return tr
