"""Jamba v0.1 [arXiv:2403.19887]: hybrid Mamba+attention, 1:7 interleave.
32L, d_model=4096, 32H GQA kv=8 (head_dim 128), d_ff=14336, vocab=65536,
MoE 16e top-2 on every 2nd sublayer. Scan unit = 8-sublayer Jamba block
(attention at position 4, Mamba elsewhere). Only 4/32 layers hold KV ->
long_500k runs with a small cache."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    pos_emb="none",  # Jamba uses no positional encoding (Mamba provides it)
    n_experts=16, experts_per_token=2, moe_every=2, moe_offset=1,
    block_len=8, attn_positions=(4,), default_kind="mamba",
    ssm_state_dim=16, ssm_expand=2, ssm_conv_width=4,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=211, head_dim=16, pos_emb="none",
    n_experts=4, experts_per_token=2, moe_every=2, moe_offset=1,
    block_len=4, attn_positions=(1,), default_kind="mamba",
    ssm_state_dim=4,
)

SETTINGS = {
    "default": CellSettings(rules="fsdp_tp_sp", param_dtype="bfloat16",
                            optimizer="adafactor"),
    "train_4k": CellSettings(microbatches=8, rules="fsdp_tp_sp",
                             param_dtype="bfloat16", optimizer="adafactor",
                             accum_dtype="bfloat16"),
    "prefill_32k": CellSettings(rules="fsdp_tp_sp",
                                param_dtype="float8_e4m3fn",
                                cache_dtype="int8", q_chunk=512),
    "decode_32k": CellSettings(rules="fsdp_tp_sp",
                               param_dtype="float8_e4m3fn",
                               cache_dtype="int8"),
    "long_500k": CellSettings(rules="fsdp_tp_sp",
                              param_dtype="float8_e4m3fn",
                              cache_dtype="int8"),
}
