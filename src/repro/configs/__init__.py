from repro.configs.registry import (  # noqa: F401
    ARCHS,
    SHAPES,
    ShapeSpec,
    get_arch,
    get_cell,
    CellSettings,
)
