"""Architecture & shape registry.

Each assigned architecture lives in ``repro/configs/<id>.py`` exposing
``CONFIG`` (exact published numbers), ``SMOKE`` (reduced same-family config
for CPU tests) and optionally ``SETTINGS`` overriding per-(shape) runtime
knobs (microbatches, rules, dtypes). ``get_cell`` resolves an
(arch x shape) cell into everything the dry-run/trainer needs.

Shapes (assigned): train_4k / prefill_32k / decode_32k / long_500k.
``decode_*``/``long_*`` lower ``serve_decode`` (one token against a seq_len
KV cache); long_500k requires sub-quadratic attention and is skipped (with a
recorded reason) for pure full-attention archs.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = (
    "whisper_small",
    "kimi_k2_1t_a32b",
    "mixtral_8x22b",
    "jamba_v01_52b",
    "qwen2_vl_7b",
    "internlm2_1_8b",
    "qwen2_0_5b",
    "phi4_mini_3_8b",
    "qwen2_5_3b",
    "rwkv6_1_6b",
)

# public ids use dashes
def canonical(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class CellSettings:
    """Per-(arch, shape) runtime knobs."""

    microbatches: int = 1
    rules: str = "baseline_dp_tp"  # sharding rule set name
    param_dtype: str = "float32"
    cache_dtype: str = "bfloat16"
    accum_dtype: str = "float32"  # gradient accumulation dtype
    optimizer: str = "adamw"  # adamw | adafactor
    q_chunk: int = 2048
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeSpec
    config: ModelConfig
    settings: CellSettings
    skip_reason: Optional[str] = None


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{canonical(arch)}")


def get_arch(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def get_cell(arch: str, shape: str) -> Cell:
    mod = _module(arch)
    cfg: ModelConfig = mod.CONFIG
    spec = SHAPES[shape]
    settings_map: Dict[str, CellSettings] = getattr(mod, "SETTINGS", {})
    settings = settings_map.get(shape, settings_map.get(
        "default", CellSettings()))
    skip = None
    if spec.name == "long_500k" and not cfg.subquadratic:
        skip = ("full quadratic attention: 500k-token decode has no bounded "
                "state; skipped per assignment (see DESIGN.md "
                "§Arch-applicability)")
    return Cell(arch=arch, shape=spec, config=cfg, settings=settings,
                skip_reason=skip)


ARCHS = ARCH_IDS
