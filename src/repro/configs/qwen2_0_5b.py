"""Qwen2-0.5B [arXiv:2407.10671]: 24L, d_model=896, 14H GQA kv=2
(head_dim 64), d_ff=4864, vocab=151936, QKV bias, tied embeddings."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, head_dim=64, qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, d_ff=96,
    vocab_size=211, head_dim=8, qkv_bias=True, tie_embeddings=True,
)

SETTINGS = {
    "default": CellSettings(),
    "train_4k": CellSettings(microbatches=2),
    "prefill_32k": CellSettings(q_chunk=512),
}
