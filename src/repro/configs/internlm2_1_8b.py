"""InternLM2-1.8B [arXiv:2403.17297]: 24L, d_model=2048, 16H GQA kv=8
(head_dim 128), d_ff=8192, vocab=92544, SwiGLU, RoPE theta 1e6."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92544, head_dim=128, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internlm2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=211, head_dim=16,
)

SETTINGS = {
    "default": CellSettings(),
    # §Perf iteration 4 tried rules="dp_pure" here (paper's pure
    # synchronous DP): collectives fell 121->25 GiB/dev but the REPLICATED
    # 92544-wide vocab head redid 9x the compute per device — hypothesis
    # REFUTED, baseline (DP+Megacore TP) restored. See EXPERIMENTS.md.
    "train_4k": CellSettings(microbatches=4),
    "prefill_32k": CellSettings(q_chunk=512),
}
