"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B]: 36L, d_model=2048, 16H GQA kv=2
(head_dim 128), d_ff=11008, vocab=151936, QKV bias, tied embeddings."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab_size=151936, head_dim=128, qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen25-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=211, head_dim=16, qkv_bias=True, tie_embeddings=True,
)

SETTINGS = {
    "default": CellSettings(),
    "train_4k": CellSettings(microbatches=4),
    "prefill_32k": CellSettings(q_chunk=512),
}
