"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892]: 24L, d_model=2048, attention-free
(32 heads x 64 head_dim WKV state), channel-mix d_ff=7168, vocab=65536.
Data-dependent decay linear recurrence; constant-size decode state ->
long_500k runs natively."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=7168,
    vocab_size=65536,
    attn_positions=(), default_kind="rwkv", rwkv_head_dim=64,
    pos_emb="none",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=128,
    vocab_size=211, attn_positions=(), default_kind="rwkv",
    rwkv_head_dim=16, pos_emb="none",
)

SETTINGS = {
    "default": CellSettings(rules="sp_only"),
    # §Perf hillclimb 2: TP-16 reshards every projection's activations for
    # an attention-free stack; SP-only keeps channel math token-local
    # (predicted: collective term 5.6s -> ~0.1s, compute-bound)
    "train_4k": CellSettings(microbatches=4, rules="sp_only",
                             param_dtype="bfloat16",
                             accum_dtype="bfloat16",
                             optimizer="adafactor"),
    "prefill_32k": CellSettings(rules="sp_only"),
}
