"""Mixtral 8x22B [arXiv:2401.04088]: 56L, d_model=6144, 48H GQA kv=8
(head_dim 128), d_ff=16384, vocab=32768, 8 experts top-2, sliding-window
attention (4096) — SWA makes long_500k decodable with a bounded KV ring."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, head_dim=128,
    rope_theta=1e6, sliding_window=4096,
    n_experts=8, experts_per_token=2, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
    vocab_size=211, head_dim=8, sliding_window=8,
    n_experts=4, experts_per_token=2,
)

SETTINGS = {
    "default": CellSettings(rules="fsdp_tp_sp", param_dtype="bfloat16",
                            optimizer="adafactor"),
    "train_4k": CellSettings(microbatches=16, rules="fsdp_tp_sp",
                             param_dtype="bfloat16", optimizer="adafactor",
                             accum_dtype="bfloat16"),
    "prefill_32k": CellSettings(rules="fsdp_tp_sp",
                                param_dtype="float8_e4m3fn",
                                cache_dtype="int8", q_chunk=512),
    "decode_32k": CellSettings(rules="fsdp_tp_sp",
                               param_dtype="float8_e4m3fn",
                               cache_dtype="int8"),
    "long_500k": CellSettings(rules="fsdp_tp_sp",
                              param_dtype="float8_e4m3fn",
                              cache_dtype="int8"),
}
