"""Whisper-small backbone [arXiv:2212.04356]: 12L enc + 12L dec, d=768,
12H (MHA), d_ff=3072, vocab=51865. Conv audio frontend is a STUB —
``input_specs`` feeds precomputed 1500-frame embeddings (3000 mel frames /
conv stride 2). GELU MLP, learned positions, LayerNorm, biases."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51865, head_dim=64,
    attn_type="causal", qkv_bias=True, pos_emb="learned", mlp_act="gelu",
    encoder_layers=12, encoder_seq=1500, cross_attention=True,
    norm_eps=1e-5,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=211, head_dim=16,
    attn_type="causal", qkv_bias=True, pos_emb="learned", mlp_act="gelu",
    encoder_layers=2, encoder_seq=12, cross_attention=True, norm_eps=1e-5,
)

SETTINGS = {
    "default": CellSettings(microbatches=2, q_chunk=1024),
    "train_4k": CellSettings(microbatches=2, q_chunk=1024),
    "prefill_32k": CellSettings(q_chunk=512),
}
