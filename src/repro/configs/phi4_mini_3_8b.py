"""Phi-4-mini-3.8B [arXiv:2412.08905]: 32L, d_model=3072, 24H GQA kv=8
(head_dim 128), d_ff=8192, vocab=200064, RoPE + SwiGLU + GQA, tied
embeddings."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=200064, head_dim=128, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="phi4-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=211, head_dim=16, tie_embeddings=True,
)

SETTINGS = {
    "default": CellSettings(),
    "train_4k": CellSettings(microbatches=4),
    "prefill_32k": CellSettings(q_chunk=512),
}
