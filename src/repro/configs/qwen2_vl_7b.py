"""Qwen2-VL-7B backbone [arXiv:2409.12191]: 28L, d_model=3584, 28H GQA kv=4
(head_dim 128), d_ff=18944, vocab=152064. M-RoPE with (16,24,24) sections
over the 64 rotary frequencies; QKV bias. The vision encoder is a STUB —
``input_specs`` supplies merged token embeddings + (3,B,S) position ids."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6,
    pos_emb="mrope", mrope_sections=(16, 24, 24),
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=211, head_dim=16,
    qkv_bias=True, pos_emb="mrope", mrope_sections=(4, 2, 2),
)

SETTINGS = {
    "default": CellSettings(),
    "train_4k": CellSettings(microbatches=4),
    "prefill_32k": CellSettings(q_chunk=512),
    "decode_32k": CellSettings(cache_dtype="int8"),
}
