"""Kimi K2 [arXiv:2501.kimi2 per assignment]: trillion-parameter MoE.
61L, d_model=7168, 64H GQA kv=8 (head_dim 112), expert d_ff=2048,
vocab=163840, 384 experts top-8 (~32B active). The paper-table arch for
pod-scale MoE training: requires FSDP + expert parallelism + Adafactor to
approach a 16 GiB/chip pod; serving uses fp8 weights + int8 KV cache
(Ironwood's FP8 story)."""

from repro.configs.registry import CellSettings
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, head_dim=112,
    rope_theta=5e4,
    n_experts=384, experts_per_token=8, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="kimi-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=32,
    vocab_size=211, head_dim=8,
    n_experts=8, experts_per_token=2, capacity_factor=1.25,
)

SETTINGS = {
    "default": CellSettings(rules="fsdp_tp_sp", param_dtype="bfloat16",
                            optimizer="adafactor"),
    "train_4k": CellSettings(microbatches=16, rules="fsdp_tp_sp",
                             param_dtype="bfloat16", optimizer="adafactor",
                             accum_dtype="bfloat16", q_chunk=2048),
    "prefill_32k": CellSettings(rules="fsdp_tp_sp",
                                param_dtype="float8_e4m3fn",
                                cache_dtype="int8", q_chunk=512),
    "decode_32k": CellSettings(rules="fsdp_tp_sp",
                               param_dtype="float8_e4m3fn",
                               cache_dtype="int8"),
}
