"""Train-step factory: microbatched grad accumulation, clipping, optimizer.

Microbatching is implemented *inside the differentiated function*: the loss
scans over microbatches with ``jax.checkpoint`` around the body, so scan-AD
itself accumulates parameter gradients in a single buffer (measured: the
manual accumulate-outside-grad formulation kept 3 fp32 grad trees alive in
the loop carry on this XLA build — 3x the memory).

Gradient dtype = accumulation dtype is controlled by casting parameters at
the loss boundary (forward compute casts to bf16 at use regardless), so
fp32 accumulation costs one params-sized fp32 tree, sharded like the params.

Cross-pod data parallelism is implicit in the shardings (batch split over
the "pod" axis) — GSPMD inserts the cross-pod gradient all-reduce exactly
as the paper's multi-pod synchronous training. The beyond-paper
``compress_pod_grads`` path replaces it with an int8 error-feedback
exchange (repro/train/compress.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer, clip_by_global_norm

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1
    grad_clip: float = 1.0
    accum_dtype: Any = jnp.float32
    compress_pod_grads: bool = False


def _batch_axis(key: str) -> int:
    return 1 if key == "positions" else 0


def split_microbatches(batch: Dict[str, Array], n: int) -> Dict[str, Array]:
    """Reshape each entry's batch axis B -> (n, B/n), microbatch axis front."""
    out = {}
    for key, val in batch.items():
        ax = _batch_axis(key)
        b = val.shape[ax]
        if b % n:
            raise ValueError(f"batch {b} not divisible by {n} microbatches")
        new_shape = val.shape[:ax] + (n, b // n) + val.shape[ax + 1:]
        v = val.reshape(new_shape)
        out[key] = jnp.moveaxis(v, ax, 0)
    return out


def init_train_state(key: jax.Array, cfg: ModelConfig, optimizer: Optimizer,
                     param_dtype=jnp.float32) -> Dict[str, Any]:
    from repro.models.params import init_params
    params = init_params(key, api.model_specs(cfg), param_dtype)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(
    cfg: ModelConfig,
    ctx: ModelContext,
    optimizer: Optimizer,
    settings: TrainSettings = TrainSettings(),
    grad_shard: Optional[Callable[[Any], Any]] = None,
) -> Callable[[Dict[str, Any], Dict[str, Array]],
              Tuple[Dict[str, Any], Dict[str, Array]]]:
    """``grad_shard``: optional tree-map applying the params' sharding
    constraints to grad-shaped trees (keeps accumulation sharded like the
    parameters rather than whatever propagation picks)."""
    if grad_shard is None:
        grad_shard = lambda tree: tree  # noqa: E731
    n = settings.microbatches

    def total_loss(params_acc, batch):
        # params_acc: params cast to accum dtype — grads inherit this dtype.
        if n == 1:
            loss, metrics = api.loss_fn(params_acc, batch, cfg, ctx)
            return loss, metrics

        mbs = split_microbatches(batch, n)

        def body(acc, mb):
            loss, metrics = api.loss_fn(params_acc, mb, cfg, ctx)
            m = {"loss": metrics["loss"] / n, "xent": metrics["xent"] / n,
                 "tokens": metrics["tokens"]}
            return acc + loss / n, m

        loss, ms = jax.lax.scan(jax.checkpoint(body),
                                jnp.zeros((), jnp.float32), mbs)
        return loss, {"loss": ms["loss"].sum(), "xent": ms["xent"].sum(),
                      "tokens": ms["tokens"].sum()}

    grad_fn = jax.value_and_grad(total_loss, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        params_acc = grad_shard(jax.tree.map(
            lambda p: p.astype(settings.accum_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params))
        (_, metrics), grads = grad_fn(params_acc, batch)
        grads = grad_shard(grads)
        grads, gnorm = clip_by_global_norm(grads, settings.grad_clip)
        # Barrier: the optimizer upcasts params to fp32 leaf-by-leaf; without
        # this, XLA hoists those converts above the whole fwd/bwd (they only
        # depend on params), keeping a full fp32 param copy live through
        # every loop (+8 GiB/device measured on the 1T-param cell).
        grads, params_upd, opt_in = jax.lax.optimization_barrier(
            (grads, params, state["opt"]))
        new_params, new_opt = optimizer.update(
            grads, opt_in, params_upd, state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": metrics["loss"], "xent": metrics["xent"],
                       "tokens": metrics["tokens"], "grad_norm": gnorm}
        return new_state, out_metrics

    return train_step
