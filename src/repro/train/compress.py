"""Compressed cross-pod gradient exchange (beyond-paper optimization).

The paper's multi-pod recipe is synchronous DP with a full-precision
gradient all-reduce across pods — the slowest links in the system (DCN, not
ICI). This module replaces that exchange with int8 quantization + error
feedback: each pod quantizes (grad + residual) to int8 with a per-tensor
scale, all-gathers the quantized tensors over the "pod" axis (1 byte/elem
vs 4), dequantizes and averages locally, and keeps the quantization error
as state for the next step (error feedback makes the compression unbiased
over time; classic 1-bit-Adam/PowerSGD-era machinery).

Implementation: ``shard_map`` over the pod axis only — inside, params are
replicated w.r.t. pods and the data/model axes stay under GSPMD (``auto``),
so the whole train step still compiles as one SPMD program.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array
PyTree = Any


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def _compressed_mean_one(g: Array, err: Array, axis: str
                         ) -> Tuple[Array, Array]:
    """Int8 error-feedback mean over a named axis. Returns (mean, new_err)."""
    compensated = g.astype(jnp.float32) + err
    q, scale = quantize_int8(compensated)
    new_err = compensated - dequantize_int8(q, scale)
    q_all = jax.lax.all_gather(q, axis)          # (n, ...) int8 on the wire
    s_all = jax.lax.all_gather(scale, axis)      # (n,) f32
    mean = jnp.mean(
        q_all.astype(jnp.float32)
        * s_all.reshape((-1,) + (1,) * g.ndim), axis=0)
    return mean.astype(g.dtype), new_err


def init_error_state(grads_like: PyTree) -> PyTree:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def make_compressed_grad_fn(
    loss_fn: Callable[[PyTree, Dict[str, Array]], Tuple[Array, Dict]],
    mesh: Mesh,
    batch_specs: Dict[str, P],
) -> Callable[[PyTree, Dict[str, Array], PyTree],
              Tuple[Tuple[Array, Dict], PyTree, PyTree]]:
    """Wrap a loss into a per-pod grad + compressed-exchange function.

    Requires params replicated over the pod axis (the paper-faithful
    baseline rules). batch_specs: pod-axis sharding per batch key.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("mesh has no 'pod' axis")
    auto = frozenset(a for a in mesh.axis_names if a != "pod")
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def local(params, batch, err):
        (loss, metrics), g = vg(params, batch)
        flat, treedef = jax.tree.flatten(g)
        eflat = treedef.flatten_up_to(err)
        out = [_compressed_mean_one(gi, ei, "pod")
               for gi, ei in zip(flat, eflat)]
        g_mean = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        return (loss, metrics), g_mean, new_err

    in_specs = (P(), batch_specs, P())
    out_specs = ((P(), P()), P(), P())
    if hasattr(jax, "shard_map"):  # jax >= 0.5
        return jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names={"pod"})
    # jax 0.4.x: partial-auto (auto={data,model}) trips an SPMD-partitioner
    # check on the scalar-scale all_gather in this XLA build; run the whole
    # exchange fully manual there instead (data/model stay unsharded inside).
    del auto
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(local, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def wire_bytes_per_step(n_params: int, pods: int,
                        compressed: bool) -> float:
    """Cross-pod bytes per device per step (for the roofline note):
    fp32 ring all-reduce moves 2(n-1)/n * 4B/elem; int8 all-gather moves
    (n-1) * 1B/elem (each device receives n-1 remote shards) + scales."""
    if compressed:
        return (pods - 1) * n_params * 1.0
    return 2.0 * (pods - 1) / pods * n_params * 4.0
