"""Sharded, asynchronous, elastic checkpointing.

Paper §Resilience point 3: checkpoint/restore is how long-running
synchronous jobs survive failures. Design points implemented here:

  * **Leaf-per-file layout** with a JSON manifest (tree structure, shapes,
    dtypes, step). No framework-opaque blobs: a checkpoint written at one
    mesh shape restores at any other (the arrays are saved unsharded and
    re-sharded by the caller's shardings on load) — this is what the
    elastic re-mesh driver relies upon after the OCS scheduler shrinks or
    regrows a slice.
  * **Async writes**: ``save`` snapshots to host (device_get) and hands the
    file I/O to a background thread — training resumes immediately, the
    goodput ledger only pays the snapshot, not the write.
  * **Atomicity**: writes go to ``<dir>.tmp`` then rename; a crash during
    write never corrupts the latest complete checkpoint. ``latest_step``
    only sees complete manifests.
  * **Integrity**: each leaf records a CRC32; restore verifies (detects the
    paper's silent-corruption concern at the storage layer).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "__"


def _flatten(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self) -> None:
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # the step restore() actually loaded — older than the requested
        # one when a corrupt checkpoint was quarantined and the previous
        # complete manifest used instead. Callers computing a replay
        # range must anchor on this, not on the step they asked for.
        self.last_restored_step: Optional[int] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: PyTree, *, blocking: bool = False
             ) -> None:
        """Snapshot to host and write asynchronously (unless blocking)."""
        self.wait()  # one outstanding write at a time
        host_state = jax.device_get(state)
        leaves = _flatten(host_state)
        treedef = jax.tree_util.tree_structure(host_state)

        def write() -> None:
            try:
                final = os.path.join(self.directory, f"step_{step:08d}")
                tmp = final + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                manifest = {"step": step, "treedef": str(treedef),
                            "leaves": {}}
                for key, arr in leaves:
                    fn = key + ".npy"
                    np.save(os.path.join(tmp, fn), arr)
                    manifest["leaves"][key] = {
                        "file": fn, "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "crc32": zlib.crc32(arr.tobytes()),
                    }
                with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                    json.dump(manifest, fh)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as exc:  # surfaced on next wait()
                self._error = exc

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for step in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{step:08d}"))

    # --------------------------------------------------------------- restore

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            # strict step_<digits> parse: skips ".tmp" partials AND
            # ".corrupt" quarantined dirs
            if not name.startswith("step_") or not name[5:].isdigit():
                continue
            if os.path.exists(os.path.join(self.directory, name,
                                           "manifest.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: PyTree,
                shard_fn: Optional[Callable[[str, np.ndarray], Any]] = None
                ) -> PyTree:
        """Restore into the structure of ``like``. ``shard_fn(key, array)``
        may device_put each leaf with new shardings (elastic re-mesh).

        A corrupt checkpoint (CRC mismatch or unreadable leaf) is
        *quarantined* — renamed to ``<dir>.corrupt``, invisible to
        ``all_steps``/``latest_step`` — and the previous complete
        checkpoint restored instead, falling back as far as needed.
        Only when no complete checkpoint survives does the original
        ``IOError`` propagate. ``last_restored_step`` records the step
        actually loaded, so replay ranges stay correct after fallback."""
        while True:
            try:
                out = self._restore_step(step, like, shard_fn)
                self.last_restored_step = step
                return out
            except IOError:
                self._quarantine(step)
                earlier = [s for s in self.all_steps() if s < step]
                if not earlier:
                    raise
                step = earlier[-1]

    def _quarantine(self, step: int) -> None:
        path = os.path.join(self.directory, f"step_{step:08d}")
        if not os.path.isdir(path):
            return
        target = path + ".corrupt"
        if os.path.exists(target):
            shutil.rmtree(target)
        os.rename(path, target)

    def _restore_step(self, step: int, like: PyTree,
                      shard_fn: Optional[Callable[[str, np.ndarray], Any]]
                      ) -> PyTree:
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for keypath, leaf in flat:
            key = _SEP.join(_path_str(p) for p in keypath)
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            try:
                arr = np.load(os.path.join(path, meta["file"]))
            except (OSError, ValueError, EOFError) as exc:
                # truncated/unreadable leaf: same corruption class as a
                # checksum mismatch (and handled by the same quarantine)
                raise IOError(f"checksum mismatch restoring {key!r} "
                              f"(corrupt checkpoint: {exc})") from exc
            if zlib.crc32(arr.tobytes()) != meta["crc32"]:
                raise IOError(f"checksum mismatch restoring {key!r} "
                              "(corrupt checkpoint)")
            want_shape = tuple(np.shape(leaf))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                    f"model {want_shape}")
            if shard_fn is not None:
                leaves.append(shard_fn(key, arr))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
