"""Training driver: config -> mesh -> sharded state -> resilient loop.

CPU-runnable end to end with reduced (smoke) configs; the same code lowers
the full configs on the production meshes (that path is exercised by
launch/dryrun.py, which only compiles).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_arch, get_smoke
from repro.core.cci import CCI_BY_NAME, CarbonLedger
from repro.core.ocs import OCSPodScheduler
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch.cells import make_optimizer
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig
from repro.obs.trace import SpanTracer
from repro.resilience.driver import FailurePlan, ResilientTrainer
from repro.train.step import TrainSettings, init_train_state, \
    make_train_step


def build_trainer(cfg: ModelConfig, *, batch: int, seq: int,
                  ckpt_dir: str, microbatches: int = 1,
                  checkpoint_every: int = 20, seed: int = 0,
                  optimizer: str = "adamw",
                  failures: Optional[Dict[int, int]] = None,
                  compute_dtype=jnp.float32,
                  metrics=None, tracer=None):
    ctx = ModelContext(compute_dtype=compute_dtype, q_chunk=2048,
                       mamba_chunk=64, rwkv_chunk=16)
    opt = make_optimizer(optimizer, total_steps=10_000)
    step_fn = jax.jit(make_train_step(
        cfg, ctx, opt, TrainSettings(microbatches=microbatches)),
        donate_argnums=(0,))
    pipeline = DataPipeline(
        DataConfig(global_batch=batch, seq_len=seq,
                   vocab_size=cfg.vocab_size, seed=seed), cfg)
    ckpt = CheckpointManager(ckpt_dir)
    sched = OCSPodScheduler(total_cubes=144)  # Ironwood-scale cube count
    sched.allocate("train", 128 * 64)
    trainer = ResilientTrainer(
        train_step=step_fn, pipeline=pipeline, ckpt=ckpt, scheduler=sched,
        job="train", checkpoint_every=checkpoint_every,
        failure_plan=FailurePlan(failures=dict(failures or {})),
        metrics=metrics, tracer=tracer)
    state = init_train_state(jax.random.key(seed), cfg, opt)
    # restore-if-present (restart semantics)
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(latest, state)
    return trainer, state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a cube failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append a timestamped JSONL metrics snapshot")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the step/ckpt/replay Chrome trace")
    ap.add_argument("--steptrace-out", default=None, metavar="PATH",
                    help="write the measured step-time trace (replayable "
                         "via fleet.perf.StepTimeModel.from_trace)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    failures = {args.fail_at: 0} if args.fail_at is not None else None
    tracer = SpanTracer() if args.trace_out else None
    trainer, state = build_trainer(
        cfg, batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches, checkpoint_every=args.ckpt_every,
        seed=args.seed, failures=failures, tracer=tracer)

    carbon = CarbonLedger(CCI_BY_NAME["ironwood"])
    t0 = time.time()
    state, ledger, losses = trainer.run(state, args.steps)
    wall = time.time() - t0
    flops_per_step = 6.0 * cfg.active_params() * args.batch * args.seq
    carbon.record_step(flops_per_step * len(losses))
    print(f"\ntrained {len(losses)} effective steps in {wall:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    # one-line goodput/step-time summary from the telemetry registry
    # (rescales is constitutionally 0 here: the trainer restores at full
    # scale — the shrink arm lives in repro.fleet)
    rs = trainer.replay_summary()
    hist = trainer.metrics.histogram("train_step_s")
    print(f"telemetry: goodput={ledger.goodput:.4f} "
          f"steps={rs['effective_steps']} "
          f"replayed={rs['replayed_steps']} rescales={rs['rescales']} "
          f"ckpts={trainer.metrics.counter('train_ckpt_saves').value:.0f} "
          f"| step p50={hist.quantile(0.5) * 1e3:.0f}ms "
          f"p95={hist.quantile(0.95) * 1e3:.0f}ms")
    print("carbon:", {k: f"{v:.3e}" for k, v in carbon.summary().items()})
    if args.metrics_out:
        trainer.metrics.to_jsonl(args.metrics_out)
        print(f"metrics snapshot appended to {args.metrics_out}")
    if args.trace_out:
        trainer.tracer.write(args.trace_out)
        print(f"chrome trace written to {args.trace_out} "
              f"({len(trainer.tracer.events)} events)")
    if args.steptrace_out:
        trainer.steptrace().write(args.steptrace_out)
        print(f"steptrace written to {args.steptrace_out}")


if __name__ == "__main__":
    main()
