"""Serving driver: continuous-batching engine over a request trace.

Two modes:

  * default — one batch of identical prompts through ``generate`` (the
    legacy smoke path, now served by the chunked engine);
  * ``--trace N`` — N requests with seeded arrivals/lengths drained by
    the continuous-batching scheduler, reporting tokens/s, occupancy and
    preemptions.

``--mesh D,M`` serves on a (data, model) mesh — on a CPU host the device
count is forced to D*M fake devices BEFORE jax initializes (same trick as
dryrun/mesh), so the sharded datapath is exercisable anywhere. Add
``--disaggregate`` for prefill/decode disaggregation with ``N``
``--prefill-workers`` handing pages over a modeled ``--link`` (ici|dcn).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --batch 4 --prompt-len 32 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --trace 16 --max-batch 4 --chunk 8 --mesh 4,2 --disaggregate
"""

from __future__ import annotations

import argparse
import os
import re
import sys
import time


def _parse_mesh_argv() -> tuple:
    """Pre-parse ``--mesh D,M`` from argv (before the jax import below:
    XLA locks the device count at first init, so the host-platform fake
    device count must be in XLA_FLAGS already)."""
    for i, a in enumerate(sys.argv):
        m = (re.fullmatch(r"--mesh=(\d+),(\d+)", a)
             or (re.fullmatch(r"(\d+),(\d+)", sys.argv[i + 1])
                 if a == "--mesh" and i + 1 < len(sys.argv) else None))
        if m:
            return int(m.group(1)), int(m.group(2))
    return None


_MESH_SHAPE = _parse_mesh_argv()
if _MESH_SHAPE is not None and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count="
        f"{_MESH_SHAPE[0] * _MESH_SHAPE[1]} "
        + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch, get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.obs.trace import SpanTracer
from repro.serve.admission import AdmissionController, AdmissionPolicy
from repro.serve.engine import ServeEngine, quantize_weights
from repro.serve.faults import FaultInjector, FaultPlan, startup_bist
from repro.serve.scheduler import Request


def make_trace(n: int, vocab: int, seed: int, *, prompt_lo=8, prompt_hi=32,
               new_lo=8, new_hi=24, mean_gap=3):
    """Deterministic multi-user arrival trace (geometric inter-arrivals)."""
    prompt_lo = min(prompt_lo, prompt_hi)
    new_lo = min(new_lo, new_hi)
    rng = np.random.default_rng(seed)
    reqs, t = [], 0
    for rid in range(n):
        t += int(rng.geometric(1.0 / max(mean_gap, 1)) - 1)
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab, int(rng.integers(prompt_lo,
                                                           prompt_hi + 1))),
            max_new=int(rng.integers(new_lo, new_hi + 1)),
            arrival=t))
    return reqs


def slo_line(engine) -> str:
    """One-line TTFT/TPOT/role-split summary from the metrics registry."""
    s = engine.slo_summary()
    return (f"slo: requests={s['requests']:.0f} "
            f"ttft p50={s['ttft_p50_s'] * 1e3:.1f}ms "
            f"p95={s['ttft_p95_s'] * 1e3:.1f}ms | "
            f"tpot p50={s['tpot_p50_s'] * 1e3:.2f}ms "
            f"p95={s['tpot_p95_s'] * 1e3:.2f}ms | "
            f"queue p50={s['queue_wait_p50_steps']:.0f} steps | "
            f"prefill {s['prefill_time_s']:.2f}s "
            f"({s['prefill_tok_s']:.0f} tok/s) / "
            f"decode {s['decode_time_s']:.2f}s "
            f"({s['decode_tok_s']:.0f} tok/s)")


def dump_telemetry(engine, args) -> None:
    """--metrics-out / --trace-out / --steptrace-out epilogue."""
    if args.metrics_out:
        engine.metrics.to_jsonl(args.metrics_out)
        print(f"metrics snapshot appended to {args.metrics_out}")
    if args.trace_out:
        engine.tracer.write(args.trace_out)
        print(f"chrome trace written to {args.trace_out} "
              f"({len(engine.tracer.events)} events)")
    if args.steptrace_out:
        engine.steptrace.write(args.steptrace_out)
        print(f"steptrace written to {args.steptrace_out} "
              f"({len(engine.steptrace)} events)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--trace", type=int, default=0,
                    help="serve N trace requests via continuous batching")
    ap.add_argument("--draft-k", type=int, default=0,
                    help="self-speculative draft length (paged archs)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prompt-prefix page sharing")
    ap.add_argument("--prefill-chunk", type=int, default=128,
                    help="span size for chunked prefill (clamped to the "
                         "window; final partial chunk buckets to pow2)")
    ap.add_argument("--quantize", choices=["none", "int8", "fp8"],
                    default="none")
    ap.add_argument("--moe-dispatch", choices=["grouped", "capacity"],
                    default="grouped",
                    help="MoE serving dispatch: sort-based dropless "
                         "grouped GEMM (default) or the dense capacity "
                         "buffer (legacy)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 paged KV pages (attention archs)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="D,M",
                    help="serve on a (data, model) mesh of D*M devices "
                         "(forced as fake host devices on CPU)")
    ap.add_argument("--rules", default="baseline_dp_tp",
                    help="AxisRules set for the serving mesh")
    ap.add_argument("--disaggregate", action="store_true",
                    help="prefill/decode disaggregation (paged archs)")
    ap.add_argument("--prefill-workers", type=int, default=1)
    ap.add_argument("--link", choices=["ici", "dcn"], default="ici",
                    help="modeled prefill->decode page-transfer link")
    ap.add_argument("--bist", action="store_true",
                    help="run the functional built-in self-test (golden "
                         "patterns through the real matmul and paged-decode "
                         "kernels) before admitting traffic; refuse to "
                         "start on mismatch")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="enable the deterministic fault injector with "
                         "this schedule seed (worker kills, KV page "
                         "flips, transfer drops, stragglers)")
    ap.add_argument("--ttft-deadline", type=int, default=None,
                    metavar="STEPS",
                    help="shed requests whose best-case TTFT exceeds this "
                         "many engine steps")
    ap.add_argument("--spec-off-depth", type=int, default=None,
                    metavar="DEPTH",
                    help="drop speculative decoding while more than DEPTH "
                         "requests queue")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append a timestamped JSONL metrics snapshot")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the request-lifecycle Chrome trace")
    ap.add_argument("--steptrace-out", default=None, metavar="PATH",
                    help="write the measured step-time trace (replayable "
                         "via fleet.perf.StepTimeModel.from_trace)")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        if jax.device_count() < d * m:
            raise SystemExit(f"--mesh {d},{m} needs {d * m} devices, "
                             f"have {jax.device_count()}")
        mesh = jax.make_mesh((d, m), ("data", "model"))

    if args.bist:
        res = startup_bist(interpret=True)
        print(f"bist: matmul max_err={res.matmul_report.max_abs_err:.3e} "
              f"paged_decode max_err={res.paged_decode_max_err:.3e} "
              f"-> {'PASS' if res.passed else 'FAIL'}")
        if not res.passed:
            raise SystemExit(
                "bist: kernel self-test failed; refusing to serve")

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    ctx = ModelContext(
        compute_dtype=jnp.float32, q_chunk=1024, mamba_chunk=16,
        rwkv_chunk=8,
        decode_cache_dtype=jnp.int8 if args.kv_int8 else None,
        moe_dispatch=args.moe_dispatch)
    params = init_params(jax.random.key(args.seed), api.model_specs(cfg))
    if args.quantize == "fp8":
        params = quantize_weights(params, jnp.float8_e4m3fn)
    elif args.quantize == "int8":
        params = quantize_weights(params, jnp.int8)  # storage demo only

    window = args.prompt_len + args.max_new
    paged = api.supports_paged_decode(cfg)
    tracer = SpanTracer() if args.trace_out else None
    faults = None
    if args.chaos is not None:
        faults = FaultInjector(FaultPlan(
            seed=args.chaos, worker_fail_rate=0.05, page_flip_rate=0.05,
            transfer_drop_rate=0.05, straggler_rate=0.05))
    admission = None
    if args.ttft_deadline is not None or args.spec_off_depth is not None:
        admission = AdmissionController(AdmissionPolicy(
            ttft_deadline_steps=args.ttft_deadline,
            spec_off_queue_depth=args.spec_off_depth))
    engine = ServeEngine(cfg, ctx, window=window, max_batch=args.max_batch,
                         chunk=args.chunk, page_size=args.page_size,
                         temperature=args.temperature,
                         draft_k=args.draft_k if paged else 0,
                         prefix_cache=(paged and not args.no_prefix_cache),
                         prefill_chunk=args.prefill_chunk,
                         mesh=mesh, rules=args.rules,
                         disaggregate=args.disaggregate,
                         prefill_workers=args.prefill_workers,
                         transfer_link=args.link, tracer=tracer,
                         faults=faults, admission=admission)
    mode = "paged" if engine.paged else "dense"
    if mesh is not None:
        mode += "/sharded"
        rep = engine.sharding_report
        print(f"mesh={rep['mesh']} rules={rep['rules']}")
        for line in rep["dropped_rules"]:
            print(f"  fallback: {line}")
    if args.disaggregate:
        mode += "/disagg"
    rng = np.random.default_rng(args.seed)

    if args.trace:
        reqs = make_trace(args.trace, cfg.vocab_size, args.seed,
                          prompt_hi=args.prompt_len, new_hi=args.max_new)
        if cfg.is_encoder_decoder:
            for req in reqs:  # enc-dec requests carry their audio features
                req.extras["enc_feats"] = rng.standard_normal(
                    (1, cfg.encoder_seq, cfg.d_model),
                    dtype=np.float32) * 0.1
        t0 = time.time()
        out = engine.run(params, reqs, key=jax.random.key(args.seed))
        wall = time.time() - t0
        toks = sum(len(v) for v in out.values())
        s = engine.scheduler
        print(f"[{mode}] {args.trace} requests, {toks} tokens in "
              f"{wall:.2f}s ({toks / wall:.1f} tok/s)")
        print(f"occupancy={s.mean_occupancy:.2f} stats={s.stats}")
        print(slo_line(engine))
        if engine.paged:
            print(f"prefix_hit_rate={engine.prefix_hit_rate:.2f} "
                  f"acceptance_length={engine.acceptance_length:.2f} "
                  f"kv={engine.kv.counters}")
        if faults is not None or admission is not None:
            print(f"[faults] {dict(engine.fault_stats.items())}")
        if args.disaggregate:
            ts = engine.transfer_stats()
            print(f"[disagg] link={ts['link']} "
                  f"transfers={ts['transfers']} "
                  f"pages={ts['transfer_pages']} "
                  f"bytes={ts['transfer_bytes']} "
                  f"stall_boundaries={ts['transfer_stall_boundaries']} "
                  f"idle_boundaries={ts['decode_idle_boundaries']}")
            print(f"[disagg] prefill queue depth mean="
                  f"{ts['prefill_depth_mean']:.2f} "
                  f"peak={ts['prefill_depth_peak']} | decode queue depth "
                  f"mean={ts['decode_depth_mean']:.2f} "
                  f"peak={ts['decode_depth_peak']} | "
                  f"pool={engine.prefill_pool.stats}")
        if mesh is not None and engine.sharding_report["dropped_rules"]:
            print("sharding fallbacks:",
                  "; ".join(engine.sharding_report["dropped_rules"]))
        dump_telemetry(engine, args)
        return

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model),
                                dtype=np.float32) * 0.1)

    t0 = time.time()
    key = jax.random.key(args.seed) if args.temperature > 0 else None
    out = engine.generate(params, batch, max_new=args.max_new,
                          temperature=args.temperature, key=key)
    wall = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[{mode}] generated {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s batch={args.batch}) "
          f"host_syncs={engine.counters['host_syncs']}")
    print(slo_line(engine))
    print("sample:", np.asarray(out[0])[:16])
    dump_telemetry(engine, args)


if __name__ == "__main__":
    main()
