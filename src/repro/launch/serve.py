"""Serving driver: batched prefill + decode with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch, get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, quantize_weights


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--quantize", choices=["none", "int8", "fp8"],
                    default="none")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=1024,
                       mamba_chunk=16, rwkv_chunk=8)
    params = init_params(jax.random.key(args.seed), api.model_specs(cfg))
    if args.quantize == "fp8":
        params = quantize_weights(params, jnp.float8_e4m3fn)
    elif args.quantize == "int8":
        params = quantize_weights(params, jnp.int8)  # storage demo only

    window = args.prompt_len + args.max_new
    engine = ServeEngine(cfg, ctx, window=window)
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model),
                                dtype=np.float32) * 0.1)

    t0 = time.time()
    key = jax.random.key(args.seed) if args.temperature > 0 else None
    out = engine.generate(params, batch, max_new=args.max_new,
                          temperature=args.temperature, key=key)
    wall = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s batch={args.batch})")
    print("sample:", np.asarray(out[0])[:16])


if __name__ == "__main__":
    main()
