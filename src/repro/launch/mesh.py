"""Production mesh construction.

Single pod: (16, 16) = 256 chips (data, model) — one TPU v5e pod.
Multi-pod: (2, 16, 16) = 512 chips (pod, data, model) — two pods; the
"pod" axis carries synchronous data parallelism exactly as the paper's
multi-pod Gemini training does.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def _auto_axis_types(n: int) -> dict:
    """axis_types kwarg when this jax has AxisType (>=0.5); Auto is already
    the default on older versions, so omit it there."""
    import jax
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests use small ones, e.g. (2,2,2) on 8 devices)."""
    import jax
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_auto_axis_types(len(shape)))


def mesh_for_devices(n: int, *, multi_pod: bool = False):
    """Scaled-down mesh with the production axis structure for n devices."""
    import jax
    if multi_pod:
        if n % 2:
            raise ValueError("multi-pod mesh needs even device count")
        side = int(np.sqrt(n // 2))
        if 2 * side * side != n:
            raise ValueError(f"cannot square {n//2} devices")
        return make_mesh((2, side, side), ("pod", "data", "model"))
    side = int(np.sqrt(n))
    if side * side != n:
        raise ValueError(f"cannot square {n} devices")
    return make_mesh((side, side), ("data", "model"))
