"""Cell builder: resolve an (arch x shape x mesh) cell into a jit-able
step function + fully-sharded input ShapeDtypeStructs.

This is the shared machinery of the dry-run, the trainer, and the server:
everything here works purely from specs (no allocation), so lowering a
1T-parameter cell is cheap.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.registry import Cell, CellSettings, ShapeSpec, get_cell
from repro.models import api
from repro.models.blocks import CACHE_LOGICAL, ModelContext
from repro.models.config import ModelConfig
from repro.models.params import axes_tree, shapes_tree
from repro.optim.optimizers import Optimizer, adafactor, adamw, \
    cosine_schedule
from repro.sharding.axes import AxisRules, RULE_SETS, logical_constraint, \
    logical_sharding, resolve_spec
from repro.train.step import TrainSettings, make_train_step

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "int8": jnp.int8,
    "float8_e4m3fn": jnp.float8_e4m3fn,
}


@dataclasses.dataclass
class BuiltCell:
    cell: Cell
    mesh: Mesh
    fn: Callable  # jit-able step function
    args: Tuple[Any, ...]  # ShapeDtypeStructs (sharded)
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    scan_trips: int  # layer-stack trip count hint
    dropped_rules: List[Tuple[str, int]]
    kind: str


def _ctx_for(cell: Cell, mesh: Mesh, rules: AxisRules) -> ModelContext:
    cache_dtype = DTYPES[cell.settings.cache_dtype]

    def shard(x, logical):
        return logical_constraint(x, logical, mesh, rules)

    return ModelContext(
        compute_dtype=jnp.bfloat16,
        q_chunk=cell.settings.q_chunk,
        shard=shard,
        decode_cache_dtype=cache_dtype,
    )


def make_optimizer(name: str, total_steps: int = 10000) -> Optimizer:
    lr = cosine_schedule(3e-4, 200, total_steps)
    if name == "adamw":
        return adamw(lr)
    if name == "adafactor":
        return adafactor(lr)
    raise ValueError(name)


def _sharded_specs(shapes, axes, mesh, rules, dropped):
    """Attach NamedShardings to a tree of ShapeDtypeStructs."""
    def one(sds: jax.ShapeDtypeStruct, logical):
        sh = logical_sharding(logical, sds.shape, mesh, rules, dropped)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)
    return jax.tree.map(
        one, shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _shardings_of(tree):
    return jax.tree.map(
        lambda s: s.sharding, tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def opt_state_axes(optimizer_name: str, param_axes, param_shapes):
    """Logical axes for optimizer state, derived from param axes."""
    if optimizer_name == "adamw":
        return {"m": param_axes, "v": param_axes}
    # adafactor: factored stats drop one dim
    def leaf(axes, sds):
        shape = sds.shape
        factored = (len(shape) >= 2 and shape[-1] >= 128
                    and shape[-2] >= 128)
        if factored:
            return {"vr": tuple(axes[:-1]), "vc": tuple(axes[:-2]) +
                    (axes[-1],)}
        return {"v": tuple(axes)}
    return jax.tree.map(
        leaf, param_axes, param_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def opt_state_shapes(optimizer_name: str, param_shapes):
    def leaf(sds: jax.ShapeDtypeStruct):
        shape = sds.shape
        if optimizer_name == "adamw":
            return {"m": jax.ShapeDtypeStruct(shape, jnp.float32),
                    "v": jax.ShapeDtypeStruct(shape, jnp.float32)}
        factored = (len(shape) >= 2 and shape[-1] >= 128
                    and shape[-2] >= 128)
        if factored:
            return {"vr": jax.ShapeDtypeStruct(shape[:-1], jnp.float32),
                    "vc": jax.ShapeDtypeStruct(shape[:-2] + shape[-1:],
                                               jnp.float32)}
        return {"v": jax.ShapeDtypeStruct(shape, jnp.float32)}
    if optimizer_name == "adamw":
        m = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                         param_shapes,
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        return {"m": m, "v": m}
    return jax.tree.map(leaf, param_shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_axes_tree(cache_shapes):
    """Logical axes for a cache tree, keyed by leaf names."""
    def walk(tree):
        out = {}
        for key, val in tree.items():
            if isinstance(val, dict):
                out[key] = walk(val)
            elif key == "pos":
                out[key] = ("batch",)
            else:
                logical = CACHE_LOGICAL[key]
                rank = len(val.shape)
                if rank == len(logical) + 1:  # stacked over blocks/layers
                    out[key] = (None, *logical)
                else:
                    out[key] = tuple(logical)
        return out
    return walk(cache_shapes)


def build_cell(arch: str, shape: str, mesh: Mesh,
               total_steps: int = 10000) -> Optional[BuiltCell]:
    cell = get_cell(arch, shape)
    if cell.skip_reason is not None:
        return None
    cfg = cell.config
    rules = RULE_SETS[cell.settings.rules]
    ctx = _ctx_for(cell, mesh, rules)
    param_dtype = DTYPES[cell.settings.param_dtype]
    dropped: List[Tuple[str, int]] = []

    specs = api.model_specs(cfg)
    p_axes = axes_tree(specs)
    p_shapes = shapes_tree(specs, param_dtype)
    p_sds = _sharded_specs(p_shapes, p_axes, mesh, rules, dropped)

    spec_kind = cell.shape.kind
    b, s = cell.shape.global_batch, cell.shape.seq_len

    if spec_kind == "train":
        optimizer = make_optimizer(cell.settings.optimizer, total_steps)
        settings = TrainSettings(
            microbatches=cell.settings.microbatches,
            accum_dtype=DTYPES[cell.settings.accum_dtype])

        def grad_shard(tree):
            return jax.tree.map(
                lambda g, la: logical_constraint(g, la, mesh, rules),
                tree, p_axes,
                is_leaf=lambda x: isinstance(x, jax.Array))

        step = make_train_step(cfg, ctx, optimizer, settings,
                               grad_shard=grad_shard)
        batch_shapes = api.train_batch_specs(cfg, b, s)
        batch_axes = {k: api.BATCH_LOGICAL[k] for k in batch_shapes}
        batch_sds = _sharded_specs(batch_shapes, batch_axes, mesh, rules,
                                   dropped)
        o_shapes = opt_state_shapes(cell.settings.optimizer, p_shapes)
        o_axes = opt_state_axes(cell.settings.optimizer, p_axes, p_shapes)
        o_sds = _sharded_specs(o_shapes, o_axes, mesh, rules, dropped)
        repl = NamedSharding(mesh, PartitionSpec())
        step_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
        state_sds = {"params": p_sds, "opt": o_sds, "step": step_sds}
        args = (state_sds, batch_sds)
        in_sh = (_shardings_of(state_sds), _shardings_of(batch_sds))
        metrics_sh = {k: repl for k in
                      ("loss", "xent", "tokens", "grad_norm")}
        out_sh = (_shardings_of(state_sds), metrics_sh)
        trips = cfg.n_blocks * cell.settings.microbatches
        return BuiltCell(cell, mesh, step, args, in_sh, out_sh,
                         donate_argnums=(0,), scan_trips=trips,
                         dropped_rules=dropped, kind="train")

    if spec_kind == "prefill":
        def prefill(params, batch):
            return api.prefill_fn(params, batch, cfg, ctx, window=s)
        batch_shapes = api.train_batch_specs(cfg, b, s)
        batch_shapes.pop("labels")
        batch_axes = {k: api.BATCH_LOGICAL[k] for k in batch_shapes}
        batch_sds = _sharded_specs(batch_shapes, batch_axes, mesh, rules,
                                   dropped)
        cache_shapes = api.cache_spec(cfg, b, s, ctx)
        cache_sds = _sharded_specs(cache_shapes, cache_axes_tree(cache_shapes),
                                   mesh, rules, dropped)
        repl = NamedSharding(mesh, PartitionSpec())
        logits_sh = logical_sharding(
            ("batch", None, "vocab"), (b, 1, cfg.vocab_size), mesh, rules)
        args = (p_sds, batch_sds)
        in_sh = (_shardings_of(p_sds), _shardings_of(batch_sds))
        out_sh = (logits_sh, _shardings_of(cache_sds))
        return BuiltCell(cell, mesh, prefill, args, in_sh, out_sh,
                         donate_argnums=(), scan_trips=cfg.n_blocks,
                         dropped_rules=dropped, kind="prefill")

    # decode
    def decode(params, token, cache):
        return api.decode_fn(params, token, cache, cfg, ctx)

    cache_shapes = api.cache_spec(cfg, b, s, ctx)
    cache_sds = _sharded_specs(cache_shapes, cache_axes_tree(cache_shapes),
                               mesh, rules, dropped)
    tok_sds = _sharded_specs(
        {"t": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
        {"t": ("batch", None)}, mesh, rules, dropped)["t"]
    logits_sh = logical_sharding(
        ("batch", None, "vocab"), (b, 1, cfg.vocab_size), mesh, rules)
    args = (p_sds, tok_sds, cache_sds)
    in_sh = (_shardings_of(p_sds), tok_sds.sharding,
             _shardings_of(cache_sds))
    out_sh = (logits_sh, _shardings_of(cache_sds))
    return BuiltCell(cell, mesh, decode, args, in_sh, out_sh,
                     donate_argnums=(2,), scan_trips=cfg.n_blocks,
                     dropped_rules=dropped, kind="decode")


def lower_cell(built: BuiltCell):
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                     out_shardings=built.out_shardings,
                     donate_argnums=built.donate_argnums)
    return jitted.lower(*built.args)
