import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective artifacts.

MUST be the first import in the process (XLA locks device count at init):
the two lines above run before any jax import, per the task spec.

For every cell this emits a JSON record with:
  - compile status and wall time;
  - memory_analysis (XLA:CPU — NOTE: the CPU backend upcasts bf16 dot
    operands to f32, inflating bf16 temps ~2x vs a real TPU; we therefore
    also record a TPU-projected estimate computed from the HLO text's
    logical dtypes: argument bytes from the input specs + per-while-loop
    carry footprints);
  - trip-count-aware FLOPs / HBM bytes / collective bytes (hlo_analysis);
  - the three roofline terms vs the TPU v5e target (core/roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen2_5_3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--single-pod]
  python -m repro.launch.dryrun --all --both-meshes --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs.registry import ARCHS, SHAPES, get_cell
from repro.core.hlo_analysis import analyze_compiled_text, shape_bytes
from repro.core.napkin import analyze_cell as napkin_cell
from repro.core.roofline import build_report, model_flops
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh

_WHILE_RE = re.compile(r"^\s*(?:ROOT\s+)?%[\w.\-]+ = (\(.*?\)) while\(",
                       re.M)


def _spec_bytes(tree) -> float:
    """Per-device argument bytes (uses each leaf's sharding)."""
    leaves = jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    total = 0.0
    for leaf in leaves:
        shape = leaf.shape
        sh = getattr(leaf, "sharding", None)
        if sh is not None:
            try:
                shape = sh.shard_shape(leaf.shape)
            except Exception:
                pass
        n = 1
        for d in shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             mesh=None) -> Dict[str, Any]:
    rec: Dict[str, Any] = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi_pod else "16x16"}
    cell = get_cell(arch, shape)
    if cell.skip_reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip_reason
        return rec
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    axis_names = tuple(mesh.axis_names)
    mesh_shape = tuple(mesh.devices.shape)
    t0 = time.time()
    try:
        built = build_cell(arch, shape, mesh)
        lowered = lower_cell(built)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as exc:  # a failure here is a bug in the system
        rec["status"] = "FAILED"
        rec["error"] = f"{type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec

    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    # TPU-projected temp estimate: while-loop carries at logical dtype widths
    carries = sorted((shape_bytes(m) for m in _WHILE_RE.findall(txt)),
                     reverse=True)
    args_spec = _spec_bytes(built.args)
    cost = analyze_compiled_text(
        txt, mesh_shape, axis_names,
        peak_memory_bytes=(args_spec + sum(carries[:2])))

    cfg = built.cell.config
    if built.kind == "train":
        tokens = cell.shape.global_batch * cell.shape.seq_len
    elif built.kind == "prefill":
        tokens = cell.shape.global_batch * cell.shape.seq_len
    else:
        tokens = cell.shape.global_batch
    mf = model_flops(cfg.active_params(), tokens,
                     training=built.kind == "train")
    notes = []
    if built.dropped_rules:
        uniq = sorted({f"{l}={d}" for l, d in built.dropped_rules})
        notes.append("replicated(non-divisible): " + ",".join(uniq[:4]))
    report = build_report(
        arch=arch, shape=shape, mesh_shape=mesh_shape,
        axis_names=axis_names, cost=cost, model_flops_global=mf,
        notes="; ".join(notes))

    rec.update({
        "status": "ok",
        "kind": built.kind,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "xla_mem": {
            "argument_gib": round(ma.argument_size_in_bytes / 2**30, 3),
            "output_gib": round(ma.output_size_in_bytes / 2**30, 3),
            "temp_gib": round(ma.temp_size_in_bytes / 2**30, 3),
            "note": "XLA:CPU inflates bf16 dot operands to f32",
        },
        "projected_mem": {
            "args_gib": round(args_spec / 2**30, 3),
            "top_carries_gib": [round(c / 2**30, 3) for c in carries[:4]],
            "peak_gib": round((args_spec + sum(carries[:2])) / 2**30, 3),
        },
        "hlo": {
            "flops_per_device": cost.flops,
            "hbm_bytes_per_device": cost.hbm_bytes,
            "collective_bytes_per_device": cost.collective_bytes(),
            "collectives_by_axes": {
                "/".join(k): v for k, v in
                cost.collective_bytes_by_axes().items()},
            "n_collectives": len(cost.collectives),
        },
        "roofline": report.row(),
    })
    nap = napkin_cell(cell, mesh_shape, axis_names)
    rec["napkin"] = {
        "t_compute_s": round(nap.t_compute, 6),
        "t_memory_s": round(nap.t_memory, 6),
        "t_collective_s": round(nap.t_collective, 6),
        "bound": nap.bound,
        "detail": {k: round(v, 4) if abs(v) < 1e6 else v
                   for k, v in nap.detail.items()},
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="output dir for JSONL")
    args = ap.parse_args()

    meshes = [True, False] if args.both_meshes else [args.multi_pod]
    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                cells.append((arch, shape, mp))

    out_path = None
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        out_path = os.path.join(args.out, "dryrun.jsonl")

    mesh_cache = {}
    n_ok = n_skip = n_fail = 0
    for arch, shape, mp in cells:
        if mp not in mesh_cache:
            mesh_cache[mp] = make_production_mesh(multi_pod=mp)
        rec = run_cell(arch, shape, multi_pod=mp, mesh=mesh_cache[mp])
        status = rec["status"]
        n_ok += status == "ok"
        n_skip += status == "skipped"
        n_fail += status == "FAILED"
        if status == "ok":
            r = rec["roofline"]
            print(f"[{rec['mesh']}] {arch:18s} {shape:12s} ok "
                  f"compile={rec['t_compile_s']:6.1f}s "
                  f"bound={r['bound']:10s} t={r['t_bound_s']:.4f}s "
                  f"frac={r['roofline_frac']:.3f} "
                  f"mem≈{rec['projected_mem']['peak_gib']:.1f}GiB",
                  flush=True)
        elif status == "skipped":
            print(f"[{rec['mesh']}] {arch:18s} {shape:12s} SKIP "
                  f"({rec['skip_reason'][:60]}...)", flush=True)
        else:
            print(f"[{rec['mesh']}] {arch:18s} {shape:12s} FAILED: "
                  f"{rec['error'][:200]}", flush=True)
        if out_path:
            with open(out_path, "a") as fh:
                fh.write(json.dumps(rec) + "\n")
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, "
          f"{n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
