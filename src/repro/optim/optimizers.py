"""Optimizers (pure-pytree, no external deps): AdamW and Adafactor.

Adafactor matters here beyond nostalgia: it is how Google trained the
paper-era large models, and its factored second moment is what lets the
1T-parameter assigned arch fit a 16 GiB/chip pod (Adam's fp32 m+v for 1e12
params is 8 TB of optimizer state; factored stats are ~1e9 elements).

Both optimizers keep state in the same sharding as the parameters (state
trees inherit the param PartitionSpecs), so FSDP-sharded params get
FSDP-sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, Array], Tuple[PyTree, PyTree]]
    # update(grads, state, params, step) -> (new_params, new_state)


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_fraction: float = 0.1) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_fraction + (1 - final_fraction)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> Tuple[PyTree, Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw(lr: Callable[[Array], Array], *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params: PyTree) -> PyTree:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mhat = m / (1 - b1 ** t)
            vhat = v / (1 - b2 ** t)
            delta = mhat / (jnp.sqrt(vhat) + eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr_t * (delta + weight_decay * pf)
            return pf.astype(p.dtype), m, v

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        mflat = treedef.flatten_up_to(state["m"])
        vflat = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(gflat, mflat, vflat, flat)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                {"m": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(treedef, [o[2] for o in out])})

    return Optimizer(init, update)


def adafactor(lr: Callable[[Array], Array], *, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    """Adafactor (Shazeer & Stern, 2018), beta1=None (no momentum)."""

    def factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor
                and p.shape[-2] >= min_dim_size_to_factor)

    def init(params: PyTree) -> PyTree:
        def leaf(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(leaf, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay)
        lr_t = lr(step)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = (vr / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), eps))[..., None] * \
                    vc[..., None, :]
                upd_ = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                news = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                upd_ = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                news = {"v": v}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(upd_ * upd_) + 1e-30)
            upd_ = upd_ / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32)
            pf = pf - lr_t * upd_ - lr_t * weight_decay * pf
            return pf.astype(p.dtype), news

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        sflat = treedef.flatten_up_to(state)
        out = [upd(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_s = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_p, new_s

    return Optimizer(init, update)
