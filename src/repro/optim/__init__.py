from repro.optim.optimizers import (  # noqa: F401
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    Optimizer,
)
