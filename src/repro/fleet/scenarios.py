"""Declarative fleet scenarios: JSON suites that run as benchmarks AND
as tests.

A scenario file (``benchmarks/scenarios/*.json``, vLLM-nightly style)
declares one deterministic mixed serve+train fleet run — the
``FleetConfig``, training ``JobSpec``s, serve ``ServeJobSpec``s, a
horizon, an optional ``baseline`` arm (section overrides re-run on the
*same seed*, e.g. autoscaling vs fixed replicas on one request trace),
and ``expect`` assertions over the flattened metrics. The same file is
loaded by ``benchmarks/bench_fleet.py`` (one row per scenario; a failed
expectation is a MISMATCH) and by ``tests/test_fleet_serve.py`` (one
pytest case per file), so every scenario is simultaneously a benchmark
row and a regression test.

``validate_scenario`` is deliberately strict: unknown keys anywhere are
errors (a typo'd knob must not silently revert to a default), and the
seed must be a literal integer — wall-clock or "auto" seeds would break
the determinism contract every consumer of these files relies on.
``scripts/docs_check.py`` runs it over every file in the scenarios
directory, so an undocumented or unloadable scenario fails tier-1.

Metric namespace (the ``expect`` targets): ``fleet/<key>`` from
``FleetSimulator.fleet_summary``, ``train/<job>/<key>`` from the job's
ledger summary plus ``steps_done``/``state_done``/``grammar_ok``, and
``serve/<job>/<key>`` from the serve ledger summary, ``slo_summary``,
``grammar_ok``, and the ``PowerModel.serve_summary`` joules-per-token
outputs. ``ref: "baseline:<metric>"`` compares against the baseline
arm's value of ``<metric>``.
"""

from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.core import hwspec
from repro.fleet.bridge import grammar_ok
from repro.fleet.jobs import SCALE_POLICIES, JobSpec
from repro.fleet.perf import ServiceTimeModel
from repro.fleet.power import PowerModel
from repro.fleet.serve_jobs import (SERVE_SCALE_POLICIES,
                                    SERVE_SHED_POLICIES, ArrivalProcess,
                                    ServeJobSpec, ServeSLO)
from repro.fleet.sim import FleetConfig, FleetSimulator

SCENARIO_SCHEMA = "repro.fleet.scenario/v1"

_TOP_KEYS = {"schema", "name", "description", "fleet", "horizon_s",
             "train_jobs", "serve_jobs", "baseline", "expect"}
_BASELINE_KEYS = {"fleet", "horizon_s", "train_jobs", "serve_jobs"}
_FLEET_KEYS = {"tpu", "total_cubes", "host_mtbf_hours", "repair_hours",
               "detect_s", "restore_s", "reconfig_s", "ckpt_write_s",
               "contiguous", "seed"}
_TRAIN_KEYS = {"name", "chips", "total_steps", "step_time_s",
               "checkpoint_every_steps", "arrival_s", "failure_steps",
               "scale_policy", "min_cubes"}
_SERVE_KEYS = {"name", "chips", "replicas", "min_replicas",
               "max_replicas", "max_batch", "scale_policy",
               "shed_policy", "control_interval_s", "spinup_s",
               "arrival_s", "scale_up_queue_per_slot",
               "scale_down_util", "slo", "arrivals", "service"}
_SLO_KEYS = {f.name for f in dataclasses.fields(ServeSLO)}
_ARRIVAL_KEYS = {f.name for f in dataclasses.fields(ArrivalProcess)}
_SERVICE_KEYS = {f.name for f in dataclasses.fields(ServiceTimeModel)} \
    - {"source"}
_EXPECT_KEYS = {"metric", "op", "value", "ref"}
_OPS = (">", ">=", "<", "<=", "==", "between")


def _check_keys(d: Any, allowed: set, where: str,
                problems: List[str]) -> bool:
    if not isinstance(d, dict):
        problems.append(f"{where}: expected an object, got "
                        f"{type(d).__name__}")
        return False
    unknown = sorted(set(d) - allowed)
    if unknown:
        problems.append(f"{where}: unknown keys {unknown}")
    return True


def _is_int(v: Any) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def validate_scenario(doc: Any) -> List[str]:
    """Structural validation; returns a list of problems (empty = ok).
    Semantic validation (positive rates, replica bounds, ...) happens in
    the dataclass constructors when the scenario actually runs."""
    problems: List[str] = []
    if not _check_keys(doc, _TOP_KEYS, "top level", problems):
        return problems
    if doc.get("schema") != SCENARIO_SCHEMA:
        problems.append(f"schema must be {SCENARIO_SCHEMA!r}, got "
                        f"{doc.get('schema')!r}")
    name = doc.get("name")
    if not isinstance(name, str) or \
            not re.fullmatch(r"[a-z0-9_]+", name or ""):
        problems.append("name must be a lowercase [a-z0-9_]+ string")
    if not isinstance(doc.get("description"), str) or \
            not doc.get("description"):
        problems.append("description is required (scenarios must be "
                        "self-documenting)")
    if not isinstance(doc.get("horizon_s"), (int, float)) or \
            isinstance(doc.get("horizon_s"), bool) or \
            not doc.get("horizon_s", 0) > 0:
        problems.append("horizon_s must be a positive number")
    fleet = doc.get("fleet")
    if fleet is None:
        problems.append("fleet section is required")
    elif _check_keys(fleet, _FLEET_KEYS, "fleet", problems):
        seed = fleet.get("seed", 0)
        if not _is_int(seed):
            # the determinism contract: no wall-clock / "auto" seeds
            problems.append(
                f"fleet.seed must be a literal integer, got {seed!r} "
                "(non-reproducible seeds are rejected)")
    names: List[str] = []
    train = doc.get("train_jobs", [])
    serve = doc.get("serve_jobs", [])
    for label, entries, keys in (("train_jobs", train, _TRAIN_KEYS),
                                 ("serve_jobs", serve, _SERVE_KEYS)):
        if not isinstance(entries, list):
            problems.append(f"{label} must be a list")
            continue
        for i, j in enumerate(entries):
            where = f"{label}[{i}]"
            if not _check_keys(j, keys, where, problems):
                continue
            if not isinstance(j.get("name"), str) or not j.get("name"):
                problems.append(f"{where}: name is required")
            else:
                names.append(j["name"])
            if label == "train_jobs" and "scale_policy" in j and \
                    j["scale_policy"] not in SCALE_POLICIES:
                problems.append(f"{where}: scale_policy must be one of "
                                f"{SCALE_POLICIES}")
            if label == "serve_jobs":
                if "scale_policy" in j and \
                        j["scale_policy"] not in SERVE_SCALE_POLICIES:
                    problems.append(
                        f"{where}: scale_policy must be one of "
                        f"{SERVE_SCALE_POLICIES}")
                if "shed_policy" in j and \
                        j["shed_policy"] not in SERVE_SHED_POLICIES:
                    problems.append(
                        f"{where}: shed_policy must be one of "
                        f"{SERVE_SHED_POLICIES}")
                for sub, allowed in (("slo", _SLO_KEYS),
                                     ("arrivals", _ARRIVAL_KEYS),
                                     ("service", _SERVICE_KEYS)):
                    if sub in j:
                        _check_keys(j[sub], allowed, f"{where}.{sub}",
                                    problems)
    if len(set(names)) != len(names):
        problems.append("duplicate job names across train_jobs/serve_jobs")
    if not train and not serve:
        problems.append("at least one train or serve job is required")
    baseline = doc.get("baseline")
    if baseline is not None:
        if _check_keys(baseline, _BASELINE_KEYS, "baseline", problems) \
                and not baseline:
            problems.append("baseline must override at least one section")
    for i, c in enumerate(doc.get("expect", [])):
        where = f"expect[{i}]"
        if not _check_keys(c, _EXPECT_KEYS, where, problems):
            continue
        if not isinstance(c.get("metric"), str):
            problems.append(f"{where}: metric is required")
        if c.get("op") not in _OPS:
            problems.append(f"{where}: op must be one of {_OPS}")
        has_value, has_ref = "value" in c, "ref" in c
        if has_value == has_ref:
            problems.append(f"{where}: exactly one of value/ref required")
        if has_ref:
            if not (isinstance(c["ref"], str) and
                    c["ref"].startswith("baseline:")):
                problems.append(f"{where}: ref must be 'baseline:<metric>'")
            elif baseline is None:
                problems.append(f"{where}: ref used without a baseline "
                                "section")
        if c.get("op") == "between" and has_value and not (
                isinstance(c["value"], list) and len(c["value"]) == 2):
            problems.append(f"{where}: 'between' takes value [lo, hi]")
    return problems


def load_scenario(path) -> Dict[str, Any]:
    """Read + validate one scenario file; raises on any problem."""
    doc = json.loads(Path(path).read_text())
    problems = validate_scenario(doc)
    if problems:
        raise ValueError(f"{path}: " + "; ".join(problems))
    return doc


def load_scenario_paths(directory) -> List[Path]:
    return sorted(Path(directory).glob("*.json"))


# ---------------------------------------------------------------- running


def _train_spec(d: Dict[str, Any]) -> JobSpec:
    kw = dict(d)
    if "failure_steps" in kw:
        kw["failure_steps"] = tuple(
            (int(s), int(c)) for s, c in kw["failure_steps"])
    return JobSpec(**kw)


def _serve_spec(d: Dict[str, Any],
                service: Optional[ServiceTimeModel]) -> ServeJobSpec:
    kw = dict(d)
    if "slo" in kw:
        kw["slo"] = ServeSLO(**kw["slo"])
    if "arrivals" in kw:
        kw["arrivals"] = ArrivalProcess(**kw["arrivals"])
    if service is not None:
        # a measured model (e.g. trace-calibrated by the tier-1 gate)
        # overrides whatever coefficients the file declares
        kw["service"] = service
    elif "service" in kw:
        kw["service"] = ServiceTimeModel(**kw["service"])
    return ServeJobSpec(**kw)


def _run_arm(doc: Dict[str, Any],
             service: Optional[ServiceTimeModel]) -> Dict[str, float]:
    cfg = FleetConfig(**doc.get("fleet", {}))
    sim = FleetSimulator(
        cfg, [_train_spec(d) for d in doc.get("train_jobs", [])],
        serve_jobs=[_serve_spec(d, service)
                    for d in doc.get("serve_jobs", [])])
    sim.run(float(doc["horizon_s"]))
    out: Dict[str, float] = {}
    for k, v in sim.fleet_summary().items():
        out[f"fleet/{k}"] = float(v)
    for name, job in sim.jobs.items():
        for k, v in job.ledger.summary().items():
            out[f"train/{name}/{k}"] = float(v)
        out[f"train/{name}/steps_done"] = float(job.base_step)
        out[f"train/{name}/state_done"] = float(job.state == "done")
        out[f"train/{name}/grammar_ok"] = float(grammar_ok(job.ledger))
    try:
        power: Optional[PowerModel] = PowerModel(hwspec.get(cfg.tpu))
        power.chip_tdp_w  # generations without a TDP anchor raise
    except ValueError:
        power = None
    for name, rt in sim.serve.items():
        for k, v in rt.ledger.summary().items():
            out[f"serve/{name}/{k}"] = float(v)
        for k, v in rt.slo_summary().items():
            out[f"serve/{name}/{k}"] = float(v)
        out[f"serve/{name}/grammar_ok"] = float(grammar_ok(rt.ledger))
        if power is not None:
            chips = rt.spec.chips * max(rt.peak_replicas, 1)
            ss = power.serve_summary(rt.ledger, chips,
                                     good_tokens=rt.good_tokens,
                                     total_tokens=rt.total_tokens)
            for k in ("energy_kwh", "joules_per_token",
                      "joules_per_good_token"):
                out[f"serve/{name}/{k}"] = float(ss[k])
    return out


def _eval(op: str, value: float, target: Any) -> bool:
    if op == ">":
        return value > target
    if op == ">=":
        return value >= target
    if op == "<":
        return value < target
    if op == "<=":
        return value <= target
    if op == "==":
        return value == target
    assert op == "between"
    lo, hi = target
    return lo <= value <= hi


def run_scenario(doc: Dict[str, Any], *,
                 service: Optional[ServiceTimeModel] = None
                 ) -> Dict[str, Any]:
    """Run one validated scenario (and its baseline arm, if declared)
    and evaluate the ``expect`` assertions. ``service`` optionally
    substitutes a measured ``ServiceTimeModel`` into every serve job of
    both arms. Deterministic: same doc + same model => identical
    result."""
    metrics = _run_arm(doc, service)
    baseline_metrics: Dict[str, float] = {}
    if doc.get("baseline"):
        arm = {k: v for k, v in doc.items()
               if k not in ("baseline", "expect")}
        arm.update(doc["baseline"])
        baseline_metrics = _run_arm(arm, service)
    checks: List[Dict[str, Any]] = []
    for c in doc.get("expect", []):
        metric, op = c["metric"], c["op"]
        value = metrics.get(metric)
        if "ref" in c:
            target: Any = baseline_metrics.get(
                c["ref"][len("baseline:"):])
        else:
            target = c["value"]
        ok = (value is not None and target is not None and
              _eval(op, value, target))
        checks.append({"metric": metric, "op": op, "value": value,
                       "target": target, "ok": ok})
    return {
        "name": doc["name"],
        "ok": all(c["ok"] for c in checks),
        "checks": checks,
        "metrics": metrics,
        "baseline_metrics": baseline_metrics,
    }
