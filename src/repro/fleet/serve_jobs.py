"""Serve jobs for the fleet simulator: inference replicas competing with
training jobs for cubes, failures, and the power budget.

The missing half of the paper's fleet story: production pods spend much
of their life *serving* (the original TPU was an inference chip with
hard latency targets), yet goodput/OCS/joules accounting is usually told
for training only. This module gives the deterministic fleet sim an
open-loop serve workload:

* **Arrivals** — a seeded non-homogeneous Poisson process
  (``ArrivalProcess``): base rate modulated by a diurnal sine and
  deterministic burst windows, drawn by Lewis-Shedler thinning from a
  per-job RNG (``np.random.default_rng([fleet_seed, crc32(job_name)])``)
  so the request trace is identical across autoscale policies and
  independent of the failure draws. Sessions are multi-turn: turn ``i``
  arrives ``i * think_time_s`` after the session start, its prompt folds
  the whole history (which the engine's prefix cache serves — later
  turns are cache hits by construction), and first turns hit a shared
  system-prefix with probability ``shared_prefix_frac``.

* **Service times** — ``fleet.perf.ServiceTimeModel``: prefill priced
  from *uncached* prompt tokens, decode from a per-chunk cost affine in
  the live batch — both calibratable from a real recorded ``ServeEngine``
  steptrace (``service_model_from_trace``), the same bridge pattern
  ``fleet/bridge.py`` uses to pin training ledgers.

* **SLO-goodput** — every request is checked against per-request
  TTFT/TPOT SLOs at admission; replica busy time splits into SLO-good
  ``steps`` (with good tokens as the step count) and SLO-violating
  ``rework`` charges on a standard ``GoodputLedger``, idle replica
  capacity charges ``idle``, spin-up/failure recovery charge
  ``restore``/``detect`` — the same five-kind grammar the bridge pins
  for training, so ``PowerModel`` prices joules-per-token with zero new
  plumbing (``PowerModel.serve_summary``).

* **Autoscaling** — replicas are OCS allocations (``"job/rK"``) that
  contend with training jobs; the ``"auto"`` policy scales up on queue
  depth or SLO violations and retires idle replicas, ``"fixed"`` only
  replaces lost replicas. Scale events ride the PR 5 elastic machinery:
  freed cubes immediately go through ``_admit_queued``/``_try_grow``.

``fleet/sim.py`` owns the event loop (``serve_*`` event kinds); this
module owns the data model and all per-job state transitions so the
handlers stay thin. docs/fleet.md has the arrival model, ledger mapping,
and the autoscale state diagram.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.goodput import GoodputLedger
from repro.core.topology import CUBE
from repro.fleet.perf import ServiceTimeModel

SERVE_SCALE_POLICIES = ("fixed", "auto")
SERVE_SHED_POLICIES = ("none", "ttft")


@dataclasses.dataclass(frozen=True)
class ServeSLO:
    """Per-request latency targets: time-to-first-token and
    time-per-output-token. A request is SLO-good iff both hold."""

    ttft_s: float = 2.0
    tpot_s: float = 0.25

    def __post_init__(self) -> None:
        if self.ttft_s <= 0 or self.tpot_s <= 0:
            raise ValueError("SLO targets must be positive")


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Open-loop session arrivals. ``rate_rps`` is *session* starts per
    second; each session issues ``~turns_mean`` requests (geometric),
    one per turn. The rate is modulated by a diurnal sine
    (``(1 + amplitude*sin(2*pi*t/period))``) and by deterministic burst
    windows (every ``burst_every_s`` seconds the rate multiplies by
    ``burst_x`` for ``burst_len_s``)."""

    rate_rps: float = 1.0
    diurnal_amplitude: float = 0.0
    diurnal_period_s: float = 86400.0
    burst_x: float = 1.0
    burst_every_s: float = 0.0  # 0 = no bursts
    burst_len_s: float = 0.0
    prompt_tokens: int = 256
    output_tokens: int = 64
    shared_prefix_frac: float = 0.0  # P(first-turn shared-prefix hit)
    prefix_frac: float = 0.5  # prompt fraction covered by such a hit
    turns_mean: float = 1.0
    think_time_s: float = 8.0

    def __post_init__(self) -> None:
        if self.rate_rps <= 0:
            raise ValueError("rate_rps must be positive")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1)")
        if self.diurnal_period_s <= 0:
            raise ValueError("diurnal_period_s must be positive")
        if self.burst_x < 1.0:
            raise ValueError("burst_x must be >= 1")
        if self.burst_every_s < 0 or self.burst_len_s < 0:
            raise ValueError("burst windows must be >= 0")
        if self.burst_every_s > 0 and self.burst_len_s > self.burst_every_s:
            raise ValueError("burst_len_s must be <= burst_every_s")
        if self.prompt_tokens < 1 or self.output_tokens < 1:
            raise ValueError("prompt/output tokens must be >= 1")
        if not 0.0 <= self.shared_prefix_frac <= 1.0:
            raise ValueError("shared_prefix_frac must be in [0, 1]")
        if not 0.0 < self.prefix_frac <= 1.0:
            raise ValueError("prefix_frac must be in (0, 1]")
        if self.turns_mean < 1.0:
            raise ValueError("turns_mean must be >= 1")
        if self.think_time_s < 0:
            raise ValueError("think_time_s must be >= 0")

    def rate_at(self, t: float) -> float:
        r = self.rate_rps
        if self.diurnal_amplitude > 0:
            r *= 1.0 + self.diurnal_amplitude * math.sin(
                2.0 * math.pi * t / self.diurnal_period_s)
        if self.burst_every_s > 0 and \
                t % self.burst_every_s < self.burst_len_s:
            r *= self.burst_x
        return r

    @property
    def peak_rate(self) -> float:
        """Upper bound on ``rate_at`` — the thinning envelope."""
        r = self.rate_rps * (1.0 + self.diurnal_amplitude)
        if self.burst_every_s > 0:
            r *= self.burst_x
        return r


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    rid: int
    turn: int
    arrival_s: float
    prompt_tokens: int
    output_tokens: int
    cached_tokens: int


@dataclasses.dataclass(frozen=True)
class ServeJobSpec:
    """One inference service: N replicas of ``chips`` chips each, fed
    from a single central queue. ``scale_policy="auto"`` targets
    ``[min_replicas, max_replicas]``; ``"fixed"`` holds ``replicas``
    (replacing lost ones) and never scales on load."""

    name: str
    chips: int
    arrivals: ArrivalProcess = ArrivalProcess()
    slo: ServeSLO = ServeSLO()
    service: ServiceTimeModel = ServiceTimeModel()
    replicas: int = 1
    min_replicas: int = 1
    max_replicas: int = 4
    max_batch: int = 8  # concurrent requests per replica
    scale_policy: str = "fixed"
    # "ttft": shed a queued request at dispatch when even its best-case
    # TTFT (wait already accrued + prefill + one chunk, batch of 1)
    # exceeds the SLO — serving it is guaranteed rework, and it
    # head-of-line-blocks requests that could still be good.
    shed_policy: str = "none"
    control_interval_s: float = 60.0
    spinup_s: float = 30.0
    arrival_s: float = 0.0  # service go-live time
    scale_up_queue_per_slot: float = 0.5
    scale_down_util: float = 0.3

    def __post_init__(self) -> None:
        if self.chips < 1:
            raise ValueError("chips must be >= 1")
        if self.scale_policy not in SERVE_SCALE_POLICIES:
            raise ValueError(
                f"scale_policy must be one of {SERVE_SCALE_POLICIES}")
        if self.shed_policy not in SERVE_SHED_POLICIES:
            raise ValueError(
                f"shed_policy must be one of {SERVE_SHED_POLICIES}")
        if not 0 <= self.min_replicas <= self.replicas <= self.max_replicas:
            raise ValueError(
                "need 0 <= min_replicas <= replicas <= max_replicas")
        if self.max_replicas < 1 or self.max_batch < 1:
            raise ValueError("max_replicas and max_batch must be >= 1")
        if self.control_interval_s <= 0:
            raise ValueError("control_interval_s must be positive")
        if self.spinup_s < 0 or self.arrival_s < 0:
            raise ValueError("spinup_s and arrival_s must be >= 0")
        if self.scale_up_queue_per_slot < 0 or \
                not 0.0 <= self.scale_down_util <= 1.0:
            raise ValueError("bad autoscale thresholds")

    @property
    def cubes_per_replica(self) -> int:
        return max(1, CUBE.cubes_for(self.chips))


@dataclasses.dataclass
class ServeReplica:
    """One live replica: an OCS allocation plus exact busy/idle wall-time
    accounting (busy = at least one request in service). Time before
    ``ready_at`` (spin-up / failure recovery) is charged as ``restore``
    by the runtime and excluded here via ``last_t = ready_at``."""

    idx: int
    name: str  # OCS allocation name, "<job>/r<idx>"
    alloc: object
    ready_at: float
    last_t: float
    busy: int = 0
    busy_s: float = 0.0
    idle_s: float = 0.0
    inflight: Dict[int, ServeRequest] = dataclasses.field(
        default_factory=dict)

    def touch(self, now: float) -> None:
        dt = now - self.last_t
        if dt > 0:
            if self.busy > 0:
                self.busy_s += dt
            else:
                self.idle_s += dt
            self.last_t = now


def _pctl(vals: List[float], q: float) -> float:
    """Deterministic nearest-rank percentile (no interpolation)."""
    if not vals:
        return 0.0
    s = sorted(vals)
    return s[min(len(s) - 1, int(q * len(s)))]


@dataclasses.dataclass
class ServeJobRuntime:
    """Mutable per-service state. The sim's ``serve_*`` handlers call the
    transition methods; everything here is deterministic given the
    fleet seed (the RNG is derived from ``[seed, crc32(name)]``)."""

    spec: ServeJobSpec
    ledger: GoodputLedger = dataclasses.field(default_factory=GoodputLedger)
    rng: Optional[np.random.Generator] = None
    state: str = "pending"  # pending -> live
    replicas: Dict[str, ServeReplica] = dataclasses.field(
        default_factory=dict)
    queue: List[ServeRequest] = dataclasses.field(default_factory=list)
    next_rid: int = 0
    next_replica: int = 0
    # counters
    arrived: int = 0
    finished: int = 0
    good: int = 0
    ttft_viol: int = 0
    tpot_viol: int = 0
    preempted: int = 0
    shed: int = 0
    good_tokens: int = 0
    total_tokens: int = 0
    viol_since_tick: int = 0
    scale_ups: int = 0
    scale_downs: int = 0
    scale_blocked: int = 0
    replicas_lost: int = 0
    peak_replicas: int = 0
    # latency samples (per started request)
    ttfts: List[float] = dataclasses.field(default_factory=list)
    tpots: List[float] = dataclasses.field(default_factory=list)
    waits: List[float] = dataclasses.field(default_factory=list)
    # completed-request log: the byte-identical determinism surface
    request_log: List[Tuple] = dataclasses.field(default_factory=list)
    # accounting already folded into the ledger (window settlement)
    closed_busy_s: float = 0.0
    closed_idle_s: float = 0.0
    _settled_busy: float = 0.0
    _settled_idle: float = 0.0
    _settled_good: int = 0
    _settled_total: int = 0

    def seed_rng(self, fleet_seed: int) -> None:
        self.rng = np.random.default_rng(
            [fleet_seed, zlib.crc32(self.spec.name.encode())])

    # ------------------------------------------------------------- arrivals

    def draw_next_session_t(self, t: float) -> float:
        """Next session start after ``t`` by Lewis-Shedler thinning
        against the process's peak-rate envelope."""
        assert self.rng is not None
        arr = self.spec.arrivals
        peak = arr.peak_rate
        while True:
            t += float(self.rng.exponential(1.0 / peak))
            if float(self.rng.uniform()) * peak <= arr.rate_at(t):
                return t

    def build_session(self, t0: float) -> List[ServeRequest]:
        """Draw one session's requests: geometric turn count, +-50%
        size jitter on the first turn, history folded into later prompts
        (fully prefix-cached — the engine's multi-turn behavior)."""
        assert self.rng is not None
        arr = self.spec.arrivals
        turns = 1 if arr.turns_mean <= 1.0 else int(
            self.rng.geometric(1.0 / arr.turns_mean))
        p = int(self.rng.integers(max(1, arr.prompt_tokens // 2),
                                  arr.prompt_tokens * 3 // 2 + 1))
        cached = 0
        if arr.shared_prefix_frac > 0 and \
                float(self.rng.uniform()) < arr.shared_prefix_frac:
            cached = int(arr.prefix_frac * p)
        tail = max(8, arr.prompt_tokens // 4)  # new user text per turn
        out: List[ServeRequest] = []
        for turn in range(turns):
            o = int(self.rng.integers(max(1, arr.output_tokens // 2),
                                      arr.output_tokens * 3 // 2 + 1))
            out.append(ServeRequest(
                rid=self.next_rid, turn=turn,
                arrival_s=t0 + turn * arr.think_time_s,
                prompt_tokens=p, output_tokens=o, cached_tokens=cached))
            self.next_rid += 1
            cached = p + o  # next turn: full history is a cache hit
            p = p + o + tail
        return out

    # -------------------------------------------------------------- routing

    def pick_replica(self, now: float) -> Optional[ServeReplica]:
        """Least-loaded ready replica with a free slot (ties by index)."""
        best = None
        for rep in self.replicas.values():
            if rep.ready_at > now or rep.busy >= self.spec.max_batch:
                continue
            if best is None or (rep.busy, rep.idx) < (best.busy, best.idx):
                best = rep
        return best

    def should_shed(self, req: ServeRequest, now: float) -> bool:
        """Admission control at dispatch: under ``shed_policy="ttft"``, a
        queued request whose *best-case* TTFT (accrued wait + prefill +
        one decode chunk at batch 1) already violates the SLO is dropped
        instead of served — it is guaranteed rework and head-of-line
        blocks requests that could still meet their deadline."""
        if self.spec.shed_policy != "ttft":
            return False
        m = self.spec.service
        best_ttft = (now - req.arrival_s
                     + m.prefill_s(req.prompt_tokens, req.cached_tokens)
                     + m.chunk_s(1))
        return best_ttft > self.spec.slo.ttft_s

    def shed_request(self, req: ServeRequest) -> None:
        self.shed += 1

    def start_service(self, rep: ServeReplica, req: ServeRequest,
                      now: float) -> Dict[str, object]:
        """Admit ``req`` into ``rep``: price the request from the service
        model at the post-admission batch, check SLOs, and return the
        ``serve_done`` payload (the sim schedules it)."""
        m = self.spec.service
        slo = self.spec.slo
        rep.touch(now)
        rep.busy += 1
        batch = rep.busy
        wait = now - req.arrival_s
        pf = m.prefill_s(req.prompt_tokens, req.cached_tokens)
        tpot = m.tpot_s(batch)
        ttft = wait + pf + m.chunk_s(batch)
        done_t = now + pf + req.output_tokens * tpot
        ok = ttft <= slo.ttft_s and tpot <= slo.tpot_s
        if ttft > slo.ttft_s:
            self.ttft_viol += 1
        if tpot > slo.tpot_s:
            self.tpot_viol += 1
        if not ok:
            self.viol_since_tick += 1
        self.ttfts.append(ttft)
        self.tpots.append(tpot)
        self.waits.append(wait)
        rep.inflight[req.rid] = req
        return {"job": self.spec.name, "replica": rep.name,
                "rid": req.rid, "start": now, "done": done_t,
                "batch": batch, "ttft": ttft, "tpot": tpot, "ok": ok}

    def finish_service(self, payload: Dict[str, object],
                       now: float) -> Optional[ServeReplica]:
        """Complete a request if its replica (and the request itself)
        still exists — stale ``serve_done`` events from replicas lost to
        failures no-op. Returns the replica so the sim can backfill from
        the queue."""
        rep = self.replicas.get(str(payload["replica"]))
        if rep is None:
            return None
        req = rep.inflight.pop(int(payload["rid"]), None)  # type: ignore
        if req is None:
            return None  # requeued after a failure; this timeline is void
        rep.touch(now)
        rep.busy -= 1
        self.finished += 1
        self.total_tokens += req.output_tokens
        if payload["ok"]:
            self.good += 1
            self.good_tokens += req.output_tokens
        self.request_log.append(
            (req.rid, req.turn, round(req.arrival_s, 9),
             round(float(payload["start"]), 9), round(now, 9),
             rep.name, int(payload["batch"]),  # type: ignore
             round(float(payload["ttft"]), 9),
             round(float(payload["tpot"]), 9), bool(payload["ok"])))
        return rep

    # ------------------------------------------------------------- scaling

    def scale_decision(self, now: float) -> Optional[str]:
        """"up"/"down"/None. ``fixed`` only tops back up to the declared
        replica count; ``auto`` scales on queue depth or SLO violations
        and retires idle capacity."""
        spec = self.spec
        live = len(self.replicas)
        if spec.scale_policy == "fixed":
            return "up" if live < spec.replicas else None
        if live < spec.min_replicas:
            return "up"
        cap = live * spec.max_batch
        qlen = len(self.queue)
        if live < spec.max_replicas and (
                qlen > spec.scale_up_queue_per_slot * cap or
                self.viol_since_tick > 0):
            return "up"
        busy = sum(r.busy for r in self.replicas.values())
        if live > max(spec.min_replicas, 1) and qlen == 0 and \
                self.viol_since_tick == 0 and \
                busy < spec.scale_down_util * cap:
            return "down"
        return None

    def idle_replica(self, now: float) -> Optional[ServeReplica]:
        """Newest fully-idle ready replica, if any (scale-down victim)."""
        best = None
        for rep in self.replicas.values():
            if rep.busy == 0 and rep.ready_at <= now:
                if best is None or rep.idx > best.idx:
                    best = rep
        return best

    def retire_replica(self, rep: ServeReplica, now: float) -> None:
        """Fold a departing replica's accounting into the closed books
        (scale-down or failure teardown)."""
        rep.touch(now)
        self.closed_busy_s += rep.busy_s
        self.closed_idle_s += rep.idle_s
        del self.replicas[rep.name]

    def requeue_inflight(self, rep: ServeReplica) -> int:
        """Push a dead replica's in-flight requests back to the front of
        the central queue (their arrival times are unchanged, so their
        eventual TTFT reflects the disruption)."""
        lost = sorted(rep.inflight.values(), key=lambda r: r.rid)
        rep.inflight.clear()
        rep.busy = 0
        self.preempted += len(lost)
        self.queue[:0] = lost
        return len(lost)

    # ----------------------------------------------------------- settlement

    def settle(self, now: float) -> None:
        """Fold the busy/idle window since the last settlement into the
        ledger, split by the window's SLO-good token fraction: good busy
        time is ``steps`` (with good tokens as the step count),
        violating busy time is ``rework``, idle capacity is ``idle`` —
        the training five-kind grammar, so the bridge and the power
        pipeline need nothing new."""
        b, i = self.closed_busy_s, self.closed_idle_s
        for rep in self.replicas.values():
            rep.touch(now)
            b += rep.busy_s
            i += rep.idle_s
        busy_w = max(b - self._settled_busy, 0.0)
        idle_w = max(i - self._settled_idle, 0.0)
        good_w = self.good_tokens - self._settled_good
        total_w = self.total_tokens - self._settled_total
        f = good_w / total_w if total_w > 0 else 1.0
        good_s = busy_w * f
        if good_s > 0 or good_w > 0:
            self.ledger.record_steps(good_s, steps=good_w,
                                     note="serve: slo-good tokens")
        if busy_w - good_s > 0 or total_w - good_w > 0:
            self.ledger.record_rework(max(busy_w - good_s, 0.0),
                                      steps=total_w - good_w,
                                      note="serve: slo-violating tokens")
        if idle_w > 0:
            self.ledger.record_idle(idle_w, note="serve: idle capacity")
        self._settled_busy, self._settled_idle = b, i
        self._settled_good = self.good_tokens
        self._settled_total = self.total_tokens

    # -------------------------------------------------------------- reports

    def slo_summary(self) -> Dict[str, float]:
        pending = len(self.queue) + sum(
            len(r.inflight) for r in self.replicas.values())
        return {
            "arrived": float(self.arrived),
            "finished": float(self.finished),
            "good_requests": float(self.good),
            "slo_goodput": (self.good_tokens / self.total_tokens
                            if self.total_tokens else 1.0),
            "good_tokens": float(self.good_tokens),
            "total_tokens": float(self.total_tokens),
            "ttft_viol": float(self.ttft_viol),
            "tpot_viol": float(self.tpot_viol),
            "preempted": float(self.preempted),
            "shed": float(self.shed),
            "pending": float(pending),
            "ttft_p50_s": _pctl(self.ttfts, 0.50),
            "ttft_p95_s": _pctl(self.ttfts, 0.95),
            "tpot_p50_s": _pctl(self.tpots, 0.50),
            "tpot_p95_s": _pctl(self.tpots, 0.95),
            "queue_wait_p50_s": _pctl(self.waits, 0.50),
            "queue_wait_p95_s": _pctl(self.waits, 0.95),
            "replicas": float(len(self.replicas)),
            "peak_replicas": float(self.peak_replicas),
            "scale_ups": float(self.scale_ups),
            "scale_downs": float(self.scale_downs),
            "scale_blocked": float(self.scale_blocked),
            "replicas_lost": float(self.replicas_lost),
        }
