"""Roofline-fed step times for the fleet simulator.

The fleet's job model charges ``step_time_s`` seconds per training step.
Constants are fine for grammar tests, but the paper's goodput numbers
ride *measured* step times, and the elastic re-scale arm needs a real
slice-size -> step-time curve: half the chips is NOT simply twice the
step time once the per-device memory and collective terms stop scaling.

This adapter prices a training step from the repo's three-term roofline
(``core.roofline.build_report``) fed by a synthetic FSDP cost report
(``core.roofline.synthetic_train_cost``) and a per-generation
``RooflineTarget`` derived from Table 1 (``core.hwspec
.roofline_target_for``), instead of a compiled dry-run artifact:

  TrainWorkload (N params, tokens/step)
    -> synthetic_train_cost(chips)        per-device FLOPs/HBM/collective
    -> build_report(target=generation)    t_compute | t_memory | t_coll
    -> t_bound / efficiency               seconds per step at that slice

``StepTimeModel`` is the callable a ``JobSpec.step_time_model`` carries:
the simulator asks it for the step time at every re-scale, so shrinking
from 32 to 24 cubes follows the generation's actual scaling curve.
``generation_step_times`` prices the same workload across all five
generations — validated against the Table-1 scaling anchors (step-time
speedup must land between the HBM-bandwidth and peak-FLOPs ratios, and
improve monotonically v2 -> Ironwood).

Also here: ``sim_checkpoint_interval_sweep``, which closes the loop on
checkpoint policy — it runs the simulator itself (synchronous writes,
contention, real failure trace) across a grid of checkpoint intervals
and checks the sim-optimal interval lands within one grid bucket of the
``search_checkpoint_interval`` closed-form optimum.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import hwspec
from repro.core.goodput import modeled_goodput
from repro.core.roofline import (RooflineReport, build_report,
                                 synthetic_train_cost)
from repro.core.topology import CUBE
from repro.fleet.jobs import JobSpec
from repro.obs.steptrace import EFFECTIVE_KINDS, StepTrace


@dataclasses.dataclass(frozen=True)
class TrainWorkload:
    """Analytic description of one training job's per-step work.

    ``n_params`` is *active* parameters (MoE: the routed subset) — the
    6*N*T napkin uses it; ``tokens_per_step`` is the global batch in
    tokens, fixed across re-scales (shrinking the slice divides the
    per-device batch, not the global one)."""

    n_params: float
    tokens_per_step: float
    param_bytes: float = 2.0
    grad_bytes: float = 4.0

    def __post_init__(self) -> None:
        if self.n_params <= 0 or self.tokens_per_step <= 0:
            raise ValueError("n_params and tokens_per_step must be positive")


@dataclasses.dataclass(frozen=True)
class StepTimeModel:
    """Callable slice-size (cubes) -> seconds per step, roofline-priced.

    ``efficiency`` discounts the perfect-overlap roofline bound to a
    realized step time (the paper-era MFU-style gap); it cancels in
    every cross-size and cross-generation *ratio*, so the scaling curves
    the elastic arm consumes are efficiency-independent."""

    tpu: str
    workload: TrainWorkload
    efficiency: float = 0.5
    pod_bw_fraction: float = 0.25

    def __post_init__(self) -> None:
        hwspec.get(self.tpu)  # fail fast on unknown generations
        if not 0.0 < self.efficiency <= 1.0:
            raise ValueError("efficiency must be in (0, 1]")

    def report(self, cubes: int) -> RooflineReport:
        """The full three-term report for a slice of ``cubes`` cubes."""
        if cubes <= 0:
            raise ValueError("cubes must be positive")
        spec = hwspec.get(self.tpu)
        target = hwspec.roofline_target_for(spec)
        chips = cubes * CUBE.chips
        wl = self.workload
        cost = synthetic_train_cost(
            n_params_active=wl.n_params,
            tokens_global=wl.tokens_per_step, chips=chips,
            param_bytes=wl.param_bytes, grad_bytes=wl.grad_bytes)
        return build_report(
            arch=f"fleet:{self.tpu}", shape="train",
            mesh_shape=[chips], axis_names=["data"], cost=cost,
            model_flops_global=6.0 * wl.n_params * wl.tokens_per_step,
            target=target, pod_bw_fraction=self.pod_bw_fraction,
            notes="synthetic FSDP cost (fleet.perf)")

    def __call__(self, cubes: int) -> float:
        return self.report(cubes).t_bound / self.efficiency

    @staticmethod
    def from_trace(trace: StepTrace,
                   kinds: Sequence[str] = EFFECTIVE_KINDS,
                   cubes_ref: int = 1) -> "MeasuredStepTimeModel":
        """Build a step-time model from a *measured* ``StepTrace``
        (real ``ServeEngine`` chunks or ``ResilientTrainer`` steps)
        instead of the analytic roofline — ROADMAP item 3's seam. The
        returned model prices a step at ``cubes_ref`` cubes as the
        measured mean and rescales ideal-linearly elsewhere; its
        ``replay()`` hands back the recorded per-step durations
        untouched for trace-replay consumers."""
        durations = tuple(trace.durations(kinds))
        if not durations:
            raise ValueError(
                f"trace from {trace.source!r} has no events of kinds "
                f"{tuple(kinds)} to model")
        return MeasuredStepTimeModel(
            durations=durations, cubes_ref=cubes_ref,
            source=trace.source)


@dataclasses.dataclass(frozen=True)
class MeasuredStepTimeModel:
    """Callable slice-size -> seconds per step, backed by measured
    durations: the mean of the recorded trace at ``cubes_ref`` cubes,
    ideal-linear rescale at other sizes (measurement fixes the anchor;
    the scaling curve stays the simulator's assumption)."""

    durations: Tuple[float, ...]
    cubes_ref: int = 1
    source: str = ""

    def __post_init__(self) -> None:
        if not self.durations:
            raise ValueError("need at least one measured duration")
        if self.cubes_ref <= 0:
            raise ValueError("cubes_ref must be positive")

    @property
    def mean_step_s(self) -> float:
        return sum(self.durations) / len(self.durations)

    def __call__(self, cubes: int) -> float:
        if cubes <= 0:
            raise ValueError("cubes must be positive")
        return self.mean_step_s * self.cubes_ref / cubes

    def replay(self) -> Tuple[float, ...]:
        """The recorded per-step durations, in execution order."""
        return self.durations


@dataclasses.dataclass(frozen=True)
class ServiceTimeModel:
    """Batch- and prefix-hit-conditioned serve service times.

    The serve-job analogue of ``MeasuredStepTimeModel``: prices a
    request's prefill from its *uncached* prompt tokens and its decode
    from a per-chunk cost that is affine in the live batch — exactly the
    two features the real ``ServeEngine`` records per steptrace event
    (``tokens``/``cached`` on prefill events, ``batch``/``steps`` on
    decode chunks), so ``from_steptrace`` can calibrate every
    coefficient from a recorded run. ``fleet.bridge
    .serve_calibration_check`` pins the round trip: a sim driven by this
    model must reproduce the measured per-chunk times."""

    prefill_s_per_token: float = 1e-4
    chunk_base_s: float = 0.02        # decode chunk at batch=1
    chunk_per_slot_s: float = 0.002   # marginal chunk cost per extra slot
    chunk_steps: int = 8              # tokens each request emits per chunk
    source: str = "analytic"

    def __post_init__(self) -> None:
        if self.prefill_s_per_token < 0 or self.chunk_per_slot_s < 0:
            raise ValueError("service-time coefficients must be >= 0")
        if self.chunk_base_s <= 0 or self.chunk_steps <= 0:
            raise ValueError("chunk_base_s and chunk_steps must be positive")

    def prefill_s(self, prompt_tokens: int, cached_tokens: int = 0) -> float:
        """Prefill wall time: only the uncached suffix costs compute
        (the engine's prefix-cache hit skips the shared prefix)."""
        return max(prompt_tokens - cached_tokens, 0) * \
            self.prefill_s_per_token

    def chunk_s(self, batch: int) -> float:
        """One decode chunk at ``batch`` live requests."""
        return self.chunk_base_s + \
            self.chunk_per_slot_s * max(batch - 1, 0)

    def tpot_s(self, batch: int) -> float:
        """Per-output-token time at ``batch`` live requests."""
        return self.chunk_s(batch) / self.chunk_steps

    def service_s(self, prompt_tokens: int, cached_tokens: int,
                  output_tokens: int, batch: int) -> float:
        return self.prefill_s(prompt_tokens, cached_tokens) + \
            output_tokens * self.tpot_s(batch)


def service_model_from_trace(
        trace: StepTrace,
        kinds: Sequence[str] = EFFECTIVE_KINDS) -> ServiceTimeModel:
    """Calibrate a ``ServiceTimeModel`` from a recorded ``ServeEngine``
    steptrace — the serve-side twin of ``StepTimeModel.from_trace``.

    Decode chunks: least-squares affine fit of chunk duration vs the
    recorded ``batch`` feature (falls back to the mean when the batch
    never varies). Prefill: through-origin per-token fit of prefill
    duration vs the recorded (already cache-discounted) ``tokens``
    feature. ``chunk_steps`` is the mean recorded ``steps`` per chunk."""
    kinds = tuple(kinds)
    batches = trace.feature_values("batch", kinds, default=1.0)
    chunk_ds = trace.durations(kinds)
    if not chunk_ds:
        raise ValueError(
            f"trace from {trace.source!r} has no decode events of kinds "
            f"{kinds} to calibrate from")
    n = len(chunk_ds)
    mean_b = sum(batches) / n
    mean_d = sum(chunk_ds) / n
    var_b = sum((b - mean_b) ** 2 for b in batches) / n
    if var_b > 1e-12:
        slope = sum((b - mean_b) * (d - mean_d)
                    for b, d in zip(batches, chunk_ds)) / n / var_b
        slope = max(slope, 0.0)
    else:
        slope = 0.0
    base = mean_d - slope * (mean_b - 1.0)  # value of the fit at batch=1
    if base <= 0.0:  # degenerate fit (tiny traces): keep the mean exact
        slope, base = 0.0, mean_d
    steps = [s for s in trace.feature_values("steps", kinds) if s > 0]
    chunk_steps = max(1, round(sum(steps) / len(steps))) if steps else 1
    tok = sum(trace.feature_values("tokens", ("prefill",)))
    per_tok = (sum(trace.durations(("prefill",))) / tok
               if tok > 0 else 0.0)
    return ServiceTimeModel(
        prefill_s_per_token=per_tok, chunk_base_s=base,
        chunk_per_slot_s=slope, chunk_steps=chunk_steps,
        source=trace.source)


def job_spec_from_trace(
    name: str,
    trace: StepTrace,
    *,
    chips: int,
    total_steps: int,
    checkpoint_every_steps: int = 100,
    arrival_s: float = 0.0,
    scale_policy: str = "queue",
    min_cubes: int = 0,
    kinds: Sequence[str] = EFFECTIVE_KINDS,
) -> JobSpec:
    """A ``JobSpec`` whose step time comes from a measured trace: the
    fleet sim runs on what the engine/trainer actually clocked."""
    cubes = max(1, CUBE.cubes_for(chips))
    model = StepTimeModel.from_trace(trace, kinds=kinds, cubes_ref=cubes)
    return JobSpec(
        name=name, chips=chips, total_steps=total_steps,
        step_time_s=model(cubes),
        checkpoint_every_steps=checkpoint_every_steps,
        arrival_s=arrival_s, scale_policy=scale_policy,
        min_cubes=min_cubes, step_time_model=model)


def generation_step_times(workload: TrainWorkload, cubes: int,
                          efficiency: float = 0.5) -> Dict[str, float]:
    """Seconds per step for the same workload on each Table-1 generation
    at a fixed slice size — the cross-generation validation surface
    (``bench_fleet`` checks the v2 -> Ironwood speedup lands between the
    Table-1 HBM-bandwidth and peak-FLOPs ratios)."""
    return {spec.name: StepTimeModel(spec.name, workload,
                                     efficiency=efficiency)(cubes)
            for spec in hwspec.GENERATIONS}


def job_spec_from_roofline(
    name: str,
    tpu: str,
    workload: TrainWorkload,
    *,
    chips: int,
    total_steps: int,
    checkpoint_every_steps: int = 100,
    arrival_s: float = 0.0,
    scale_policy: str = "queue",
    min_cubes: int = 0,
    efficiency: float = 0.5,
) -> JobSpec:
    """A ``JobSpec`` whose step time — at full size AND at every elastic
    re-scale — comes from the roofline instead of a constant."""
    model = StepTimeModel(tpu, workload, efficiency=efficiency)
    return JobSpec(
        name=name, chips=chips, total_steps=total_steps,
        step_time_s=model(CUBE.cubes_for(chips)),
        checkpoint_every_steps=checkpoint_every_steps,
        arrival_s=arrival_s, scale_policy=scale_policy,
        min_cubes=min_cubes, step_time_model=model)


# ---------------------------------------------------------------------------
# Checkpoint-interval policy: the simulator as ground truth.
# ---------------------------------------------------------------------------


def sim_checkpoint_interval_sweep(
    *,
    mtbf_hours: float = 2.0,
    detect_s: float = 15.0,
    restore_s: float = 60.0,
    checkpoint_write_s: float = 10.0,
    step_time_s: float = 1.0,
    points: int = 9,
    lo_s: float = 90.0,
    hi_s: float = 7200.0,
    mean_failures: float = 40.0,
    seed: int = 0,
    tpu: str = "tpu_v4",
) -> Dict[str, object]:
    """Validate ``search_checkpoint_interval`` against the simulator.

    Runs one single-cube job (plus one spare cube) under the *same*
    seeded failure trace for every interval on a log-spaced grid —
    failure/repair draws are independent of the job timeline, so every
    arm sees identical failures — with synchronous checkpoint writes, and
    compares the sim-optimal interval to the closed-form
    ``modeled_goodput`` optimum over the same grid. The two argmaxes
    should agree within one grid bucket (the Young/Daly first-order
    claim, now with detect/restore and write stalls priced by both
    sides)."""
    # a lazy import: fleet.sim imports fleet.jobs, which this module
    # shares; importing sim at module scope would be cycle-free today but
    # this keeps perf importable from jobs-level code too
    from repro.fleet.sim import FleetConfig, FleetSimulator

    spec = hwspec.get(tpu)
    hosts_per_cube = max(1, CUBE.chips // spec.tpus_per_host)
    horizon_s = mean_failures * mtbf_hours * 3600.0
    intervals: List[float] = []
    sim_goodput: List[float] = []
    model_goodput: List[float] = []
    for i in range(points):
        t = lo_s * (hi_s / lo_s) ** (i / (points - 1))
        every = max(1, round(t / step_time_s))
        t_q = every * step_time_s  # the interval the sim actually runs
        intervals.append(t_q)
        cfg = FleetConfig(
            tpu=tpu, total_cubes=2,
            # cube-level MTBF == the target job MTBF (one-cube job)
            host_mtbf_hours=mtbf_hours * hosts_per_cube,
            repair_hours=0.25, detect_s=detect_s, restore_s=restore_s,
            reconfig_s=0.0, ckpt_write_s=checkpoint_write_s, seed=seed)
        job = JobSpec(name="probe", chips=CUBE.chips,
                      total_steps=10**9, step_time_s=step_time_s,
                      checkpoint_every_steps=every)
        sim = FleetSimulator(cfg, [job])
        sim.run(horizon_s, check_invariants=False)
        sim_goodput.append(sim.jobs["probe"].ledger.goodput)
        model_goodput.append(modeled_goodput(
            mtbf_hours=mtbf_hours, detect_s=detect_s, restore_s=restore_s,
            checkpoint_interval_s=t_q,
            checkpoint_write_s=checkpoint_write_s))
    sim_best = max(range(points), key=lambda i: sim_goodput[i])
    model_best = max(range(points), key=lambda i: model_goodput[i])
    return {
        "intervals_s": intervals,
        "sim_goodput": sim_goodput,
        "model_goodput": model_goodput,
        "sim_best_index": sim_best,
        "model_best_index": model_best,
        "sim_best_interval_s": intervals[sim_best],
        "model_best_interval_s": intervals[model_best],
        "bucket_delta": abs(sim_best - model_best),
        "agree_within_one_bucket": abs(sim_best - model_best) <= 1,
    }
