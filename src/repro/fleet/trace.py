"""Chrome-trace export of fleet simulations — thin re-export.

The recorder now lives in ``repro.obs.trace`` as a shim over the shared
``SpanTracer``, so fleet-sim events, serve-engine request spans, and
trainer step/replay spans serialize through one schema and can merge
into one timeline. This module keeps the historical import path
(``repro.fleet.trace.TraceRecorder``) and constants alive.
"""

from __future__ import annotations

from repro.obs.trace import (_COLORS, _PHASE_TID, _POD_PID, SpanTracer,
                             TraceRecorder)

__all__ = ["TraceRecorder", "SpanTracer",
           "_COLORS", "_PHASE_TID", "_POD_PID"]
