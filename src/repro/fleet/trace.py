"""Chrome-trace export of fleet simulations.

Emits the Trace Event Format JSON that chrome://tracing / Perfetto load
directly: one process row per job (complete "X" events for train / rework
/ restore / queued / ckpt-write phases, in microseconds) plus a pod-level
row of instant "i" events for failures, repairs, SDC detections, OCS
reconfigurations, elastic re-scales, and install waves, and pod counters
(spare cubes, installed cubes, concurrent checkpoint writers). The same
idea as trace-driven replay tooling (byteprofile-style timelines),
pointed at fleet state instead of ops.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

_POD_PID = 0  # process row for pod-level instants
_PHASE_TID = 1

_COLORS = {
    "train": "good",
    "rework": "bad",
    "restore": "terrible",
    "detect": "yellow",
    "queued": "grey",
    "ckpt": "olive",
}


class TraceRecorder:
    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self._job_pid: Dict[str, int] = {}

    def _pid(self, job: str) -> int:
        if job not in self._job_pid:
            pid = len(self._job_pid) + 1
            self._job_pid[job] = pid
            self.events.append({
                "ph": "M", "pid": pid, "name": "process_name",
                "args": {"name": f"job:{job}"},
            })
        return self._job_pid[job]

    def duration(self, job: str, phase: str, t0_s: float, dur_s: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A complete event on the job's row; zero-length phases (async
        checkpoint marks) become instants so they stay visible."""
        ev: Dict[str, Any] = {
            "pid": self._pid(job), "tid": _PHASE_TID, "name": phase,
            "ts": t0_s * 1e6, "cat": "fleet",
        }
        if _COLORS.get(phase):
            ev["cname"] = _COLORS[phase]
        if args:
            ev["args"] = args
        if dur_s <= 0.0:
            ev.update(ph="i", s="t")
        else:
            ev.update(ph="X", dur=dur_s * 1e6)
        self.events.append(ev)

    def instant(self, name: str, t_s: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        self.events.append({
            "ph": "i", "s": "g", "pid": _POD_PID, "tid": 0, "name": name,
            "ts": t_s * 1e6, "cat": "pod",
            **({"args": args} if args else {}),
        })

    def counter(self, name: str, t_s: float,
                values: Dict[str, float]) -> None:
        self.events.append({
            "ph": "C", "pid": _POD_PID, "tid": 0, "name": name,
            "ts": t_s * 1e6, "args": dict(values),
        })

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        meta = [{"ph": "M", "pid": _POD_PID, "name": "process_name",
                 "args": {"name": "pod"}}]
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
