"""Deterministic discrete-event engine for the fleet simulator.

A seeded event heap and nothing else: no wall clock, no threads. Ties in
time break by insertion order (a monotonically increasing sequence
number), so two runs with the same seed and the same schedule calls pop
the exact same event sequence — the determinism property the fleet tests
pin (and that the elastic re-scale arm inherits: same seed, same
shrink/grow-back sequence). Stochastic arrivals (failures, corruptions)
draw from the engine's ``rng``; callers that want a purely deterministic
timeline simply never touch it.

The simulator's event vocabulary rides this engine unchanged: arrival /
complete / cube_fail / plan_fail / repair / sdc_corrupt / sdc_detect,
plus (PR 5) ``ckpt_write`` (synchronous snapshot stalls) and ``install``
(incremental-deployment waypoints). Stale timelines are invalidated by
per-job epochs, not cancellation, so the heap may hold superseded
events; ``cancel`` exists for the few cases (SDC map-out) that must
retract a pending failure.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, Iterator, List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence. ``payload`` is owned by the scheduler's
    handler; ``seq`` is the deterministic tiebreaker and identity."""

    time: float
    seq: int
    kind: str
    payload: Dict[str, Any]

    def __getitem__(self, key: str) -> Any:
        return self.payload[key]


class EventEngine:
    def __init__(self, seed: int = 0):
        self.now = 0.0
        self.rng = np.random.default_rng(seed)
        self._heap: List[tuple] = []
        self._seq = 0
        self._cancelled: set = set()
        self.processed = 0

    # -- scheduling ----------------------------------------------------------

    def schedule_at(self, time: float, kind: str, **payload: Any) -> Event:
        if time < self.now:
            raise ValueError(
                f"cannot schedule {kind!r} at {time} < now {self.now}")
        ev = Event(time=float(time), seq=self._seq, kind=kind,
                   payload=payload)
        self._seq += 1
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def schedule(self, delay: float, kind: str, **payload: Any) -> Event:
        return self.schedule_at(self.now + max(0.0, delay), kind, **payload)

    def cancel(self, ev: Event) -> None:
        self._cancelled.add(ev.seq)

    def draw_exponential(self, mean: float) -> float:
        return float(self.rng.exponential(mean))

    # -- draining ------------------------------------------------------------

    def peek_time(self) -> Optional[float]:
        while self._heap and self._heap[0][1] in self._cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def pop(self) -> Optional[Event]:
        """Next live event, advancing ``now`` to its time."""
        while self._heap:
            _, seq, ev = heapq.heappop(self._heap)
            if seq in self._cancelled:
                self._cancelled.discard(seq)
                continue
            self.now = ev.time
            self.processed += 1
            return ev
        return None

    def drain_until(self, until: float) -> Iterator[Event]:
        """Yield events with time <= until (advancing ``now``); events
        beyond the horizon stay queued. Finally advances ``now`` to
        ``until``."""
        while True:
            t = self.peek_time()
            if t is None or t > until:
                break
            ev = self.pop()
            assert ev is not None
            yield ev
        self.now = max(self.now, until)
