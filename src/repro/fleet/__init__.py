"""Fleet simulator: discrete-event multi-job pod simulation.

Composes the repo's per-component paper models — OCS cube scheduling
(`core.ocs`), goodput accounting (`core.goodput`), SDC detection
statistics (`core.sdc`), and per-generation TDP/perf (`core.hwspec`) —
into one executable fleet story: many concurrent training *and serving*
jobs on a simulated pod, over days of simulated time, with failures,
repairs, OCS reconfigurations, silent-data-corruption rollbacks,
autoscaled inference replicas under TTFT/TPOT SLOs, and power/carbon
integration per job.
"""

from repro.fleet.bridge import (GRAMMAR_KINDS, grammar_ok, run_bridge,
                                serve_calibration_check,
                                simulate_trainer_plan)
from repro.fleet.events import Event, EventEngine
from repro.fleet.jobs import (JobRuntime, JobSpec,
                              optimal_checkpoint_interval_s,
                              search_checkpoint_interval)
from repro.fleet.perf import (MeasuredStepTimeModel, ServiceTimeModel,
                              StepTimeModel, TrainWorkload,
                              generation_step_times,
                              job_spec_from_roofline, job_spec_from_trace,
                              service_model_from_trace,
                              sim_checkpoint_interval_sweep)
from repro.fleet.power import PowerModel, generation_efficiency_table, \
    sustainability_ratios
from repro.fleet.scenarios import (SCENARIO_SCHEMA, load_scenario,
                                   load_scenario_paths, run_scenario,
                                   validate_scenario)
from repro.fleet.serve_jobs import (SERVE_SCALE_POLICIES, ArrivalProcess,
                                    ServeJobRuntime, ServeJobSpec,
                                    ServeReplica, ServeRequest, ServeSLO)
from repro.fleet.sim import FleetConfig, FleetSimulator
from repro.fleet.trace import TraceRecorder

__all__ = [
    "GRAMMAR_KINDS", "grammar_ok", "run_bridge",
    "serve_calibration_check", "simulate_trainer_plan",
    "Event", "EventEngine", "JobRuntime", "JobSpec",
    "optimal_checkpoint_interval_s", "search_checkpoint_interval",
    "MeasuredStepTimeModel", "ServiceTimeModel", "StepTimeModel",
    "TrainWorkload", "generation_step_times", "job_spec_from_roofline",
    "job_spec_from_trace", "service_model_from_trace",
    "sim_checkpoint_interval_sweep",
    "PowerModel", "generation_efficiency_table", "sustainability_ratios",
    "SCENARIO_SCHEMA", "load_scenario", "load_scenario_paths",
    "run_scenario", "validate_scenario",
    "SERVE_SCALE_POLICIES", "ArrivalProcess", "ServeJobRuntime",
    "ServeJobSpec", "ServeReplica", "ServeRequest", "ServeSLO",
    "FleetConfig", "FleetSimulator", "TraceRecorder",
]
