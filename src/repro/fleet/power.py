"""Power and carbon integration for simulated fleet jobs.

Bridges three of the repo's paper models: the absolute-TDP anchor in
``core.hwspec`` (the paper's Relative Pod TDP row anchored at the public
TPU v2 280 W chip), the goodput ledger's wall-time partition, and the
CCI records of ``core.cci``. A job's energy integrates TDP over its
ledger: chips draw full TDP while stepping or reworking and an idle
fraction while detecting/restoring/queued. Effective FLOPs count only
*productive* step time (goodput discounts rework), so the J-per-
effective-FLOP and gCO2e-per-effective-FLOP outputs respond to both the
hardware generation (perf/W) and the fleet's resilience behavior — the
paper's sustainability and goodput stories in one number.

Elastic caveat: ``job_summary(ledger, chips)`` integrates at a fixed
chip count. A job that spent part of its life re-scaled to a smaller
slice held fewer chips during those segments, so passing its full
``spec.chips`` bounds energy from above (conservative for the
sustainability ratios, which are cross-generation and cancel the
fleet behavior).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import hwspec
from repro.core.cci import CCI_BY_NAME, CCIRecord
from repro.core.goodput import GoodputLedger

# Time the chips are actually clocking the training step.
_BUSY_KINDS = ("steps", "rework")


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Per-generation energy/carbon integrator.

    ``mfu`` discounts peak to realized FLOP/s during productive step
    time; ``idle_power_fraction`` is the draw while the slice is held but
    not stepping (detect, restore, queued); ``grid_gco2e_per_kwh`` is the
    operational emissions factor (market-based CFE-credited grids sit far
    below location-based ones — the paper's footnote 7 contrast).
    """

    spec: hwspec.TPUSpec
    mfu: float = 0.4
    idle_power_fraction: float = 0.15
    grid_gco2e_per_kwh: float = 100.0

    @property
    def chip_tdp_w(self) -> float:
        w = hwspec.chip_tdp_watts(self.spec)
        if w is None:
            raise ValueError(
                f"{self.spec.name}: no TDP anchor (paper gives no "
                "relative TDP row)")
        return w

    @property
    def cci(self) -> Optional[CCIRecord]:
        return CCI_BY_NAME.get(self.spec.name)

    def job_energy_joules(self, ledger: GoodputLedger, chips: int) -> float:
        t = ledger.totals()
        busy_s = sum(t.get(k, 0.0) for k in _BUSY_KINDS)
        held_s = ledger.total_seconds - busy_s
        w = self.chip_tdp_w * chips
        return busy_s * w + held_s * w * self.idle_power_fraction

    def job_effective_flops(self, ledger: GoodputLedger,
                            chips: int) -> float:
        per_chip = self.spec.peak_tflops * 1e12 * self.mfu
        return ledger.productive_seconds * chips * per_chip

    def job_summary(self, ledger: GoodputLedger,
                    chips: int) -> Dict[str, float]:
        energy_j = self.job_energy_joules(ledger, chips)
        eff = self.job_effective_flops(ledger, chips)
        eflops = eff / 1e18
        kwh = energy_j / 3.6e6
        out = {
            "energy_j": energy_j,
            "energy_kwh": kwh,
            "effective_eflops": eflops,
            "joules_per_eflop": energy_j / eflops if eflops else float("inf"),
            "gco2e_operational": kwh * self.grid_gco2e_per_kwh,
        }
        rec = self.cci
        if rec is not None:
            out["gco2e_embodied"] = rec.embodied * eflops
            out["gco2e_total"] = out["gco2e_operational"] + \
                out["gco2e_embodied"]
            out["gco2e_per_eflop"] = (out["gco2e_total"] / eflops
                                      if eflops else float("inf"))
        return out

    def serve_summary(self, ledger: GoodputLedger, chips: int, *,
                      good_tokens: float,
                      total_tokens: float) -> Dict[str, float]:
        """Joules-per-token for a serve job, through the *same* ledger
        integration training uses: SLO-good busy time is ``steps``,
        violating busy time is ``rework`` (full TDP either way — the
        chips clocked those tokens), idle/spin-up/recovery draw the idle
        fraction. ``chips`` is per-replica chips times the replica count
        the ledger describes (an upper bound under autoscaling, like the
        elastic caveat above)."""
        energy_j = self.job_energy_joules(ledger, chips)
        out = {
            "energy_j": energy_j,
            "energy_kwh": energy_j / 3.6e6,
            "good_tokens": good_tokens,
            "total_tokens": total_tokens,
            "joules_per_token": (energy_j / total_tokens
                                 if total_tokens else float("inf")),
            "joules_per_good_token": (energy_j / good_tokens
                                      if good_tokens else float("inf")),
        }
        return out


# ---------------------------------------------------------------------------
# Cross-generation sustainability trend (Figure 5 re-derived in joules).
# ---------------------------------------------------------------------------


def generation_efficiency_table(mfu: float = 1.0) -> Dict[str, float]:
    """Joules per peak ExaFLOP for each generation, from the anchored TDP
    and Table 1 peak (FP8 where supported — the paper's normalization).
    At mfu=1 this is exactly the inverse of the paper's perf/Watt row up
    to the anchoring constant."""
    out = {}
    for spec in hwspec.GENERATIONS:
        pod_w = hwspec.pod_tdp_watts(spec)
        assert pod_w is not None
        pod_flops = spec.pod_size * spec.peak_tflops * 1e12 * mfu
        out[spec.name] = pod_w / (pod_flops / 1e18)
    return out


def sustainability_ratios() -> Dict[str, float]:
    """Ironwood-vs-v2 improvement, both energy- and carbon-normalized.

    At fixed grid intensity, gCO2e/FLOP is proportional to J/FLOP, so
    both ratios reduce to the paper's ~29x perf/Watt claim; we recompute
    from the anchored absolute numbers so the derivation chain
    (TDP anchor -> joules -> CO2e) is itself exercised."""
    table = generation_efficiency_table()
    j_ratio = table["tpu_v2"] / table["ironwood"]
    rel = hwspec.IRONWOOD.rel_pod_tflops_per_watt / \
        hwspec.TPU_V2.rel_pod_tflops_per_watt
    return {
        "joules_per_flop_improvement_x": j_ratio,
        "co2e_per_flop_improvement_x": j_ratio,  # fixed-grid identity
        "paper_perf_per_watt_x": rel,
    }
