"""Validate the fleet simulator against the real resilient trainer.

``run_bridge`` executes the same failure plan twice:

  * for real — a smoke-scale ``ResilientTrainer`` run (actual model,
    actual checkpoints, actual OCS substitutions, measured seconds);
  * in the simulator — one fleet job with the identical plan
    (checkpoint cadence, failure steps, cube ids) and modeled seconds.

The two goodput ledgers must agree *event-for-event in structure*
(``GoodputLedger.structure()``: the merged (kind, steps) sequence —
bootstrap idle, step runs, checkpoint marks, detect/restore/rework
triplets with identical rework step counts). Durations differ by
construction (measured vs modeled); the grammar must not.

``GRAMMAR_KINDS`` is the pinned vocabulary both sides speak. Elastic
re-scale and synchronous checkpoint writes extend the *simulator's*
story, but every new charge stays inside this vocabulary (re-scale
markers are ``idle``, write stalls are ``idle``, re-scale restores are
``restore``/``rework``) — ``grammar_ok`` asserts exactly that, so the
bridge contract survives the elastic arm unchanged.
"""

from __future__ import annotations

import shutil
import tempfile
from typing import Dict, Optional

from repro.core.goodput import GoodputLedger
from repro.fleet.jobs import JobSpec
from repro.fleet.sim import FleetConfig, FleetSimulator

# The pinned ledger vocabulary: every event either side ever records.
GRAMMAR_KINDS = ("steps", "rework", "detect", "restore", "idle")


def grammar_ok(ledger: GoodputLedger) -> bool:
    """True iff every ledger event speaks the pinned five-kind grammar."""
    return all(e.kind in GRAMMAR_KINDS for e in ledger.events)

# Mirrors launch.train.build_trainer's pod: Ironwood-scale cube count,
# one 8192-chip job (cubes 0..127), 16 spares.
_TOTAL_CUBES = 144
_JOB_CHIPS = 128 * 64


def simulate_trainer_plan(
    *,
    total_steps: int,
    checkpoint_every: int,
    failures: Dict[int, int],
    step_time_s: float = 1.0,
    detect_s: float = 0.05,
    restore_s: float = 0.05,
    tpu: str = "ironwood",
) -> GoodputLedger:
    """Run the fleet simulator over the exact failure plan a
    ResilientTrainer would be given, returning the simulated ledger."""
    spec = JobSpec(
        name="train", chips=_JOB_CHIPS, total_steps=total_steps,
        step_time_s=step_time_s,
        checkpoint_every_steps=checkpoint_every,
        failure_steps=tuple(sorted(failures.items())))
    cfg = FleetConfig(tpu=tpu, total_cubes=_TOTAL_CUBES,
                      host_mtbf_hours=None, detect_s=detect_s,
                      restore_s=restore_s, reconfig_s=0.0, sdc=None)
    sim = FleetSimulator(cfg, [spec])
    # horizon: each failure costs detect + restore + rework, and rework
    # is bounded by the full history (checkpoint_every > total_steps)
    sim.run((1 + len(failures)) * total_steps * step_time_s
            + len(failures) * (detect_s + restore_s) + 1.0)
    job = sim.jobs["train"]
    assert job.state == "done", f"sim job did not finish: {job.state}"
    return job.ledger


def serve_calibration_check(trace, *, tol: float = 0.25,
                            requests: int = 160) -> Dict[str, float]:
    """The serve-side bridge: pin the sim's per-chunk service times to a
    *measured* ``ServeEngine`` steptrace.

    Calibrates a ``ServiceTimeModel`` from the trace, then drives a
    one-replica serve sim to saturation at the trace's mean recorded
    batch and compares the realized per-chunk decode time
    (``tpot * chunk_steps`` of steady-state admissions) against the
    ``MeasuredStepTimeModel`` replay mean of the same trace. ``ok`` iff
    the relative error is within ``tol`` — the tier-1 calibration gate
    (``scripts/trace_gate.py``) fails on a miss."""
    from repro.fleet.perf import StepTimeModel, service_model_from_trace
    from repro.fleet.serve_jobs import (ArrivalProcess, ServeJobSpec,
                                        ServeSLO)
    from repro.obs.steptrace import EFFECTIVE_KINDS

    model = service_model_from_trace(trace)
    measured = StepTimeModel.from_trace(trace)
    batches = [float(e.features.get("batch", 1.0))
               for e in trace.events if e.kind in EFFECTIVE_KINDS]
    target_b = max(1, round(sum(batches) / len(batches)))
    out_tokens = model.chunk_steps * 4
    service_s = model.service_s(1, 0, out_tokens, target_b)
    # arrivals outpace the replica's saturated throughput (target_b
    # requests per service_s) 2x, so after warm-up every admission
    # happens at a full batch of target_b
    horizon = 2.0 * requests * service_s / target_b + 1.0
    arr = ArrivalProcess(rate_rps=2.0 * target_b / service_s,
                         prompt_tokens=2, output_tokens=out_tokens,
                         turns_mean=1.0)
    svc = ServeJobSpec(
        name="cal", chips=64, arrivals=arr,
        slo=ServeSLO(ttft_s=1e9, tpot_s=1e9), service=model,
        replicas=1, max_replicas=1, max_batch=target_b,
        scale_policy="fixed", spinup_s=0.0)
    sim = FleetSimulator(FleetConfig(tpu="ironwood", total_cubes=1),
                         [], serve_jobs=[svc])
    sim.run(horizon)
    log = sim.serve["cal"].request_log
    chunks = [tpot * model.chunk_steps
              for (_, _, _, _, _, _, batch, _, tpot, _) in log
              if batch == target_b]
    measured_mean = measured.mean_step_s
    sim_mean = (sum(chunks) / len(chunks)) if chunks else 0.0
    rel_err = (abs(sim_mean - measured_mean) / measured_mean
               if measured_mean else float("inf"))
    return {
        "target_batch": float(target_b),
        "steady_admissions": float(len(chunks)),
        "sim_chunk_s": sim_mean,
        "measured_chunk_s": measured_mean,
        "rel_err": rel_err,
        "ok": float(len(chunks) >= 8 and rel_err <= tol),
    }


def run_bridge(
    *,
    arch: str = "qwen2_0_5b",
    steps: int = 18,
    checkpoint_every: int = 6,
    failures: Optional[Dict[int, int]] = None,
    batch: int = 2,
    seq: int = 16,
) -> Dict[str, object]:
    """Real run vs simulated run of one failure plan; returns both
    structures, both goodputs, and whether the structures match."""
    from repro.configs.registry import get_smoke
    from repro.launch.train import build_trainer
    from repro.resilience.driver import StragglerPolicy

    failures = dict(failures if failures is not None else {9: 0, 14: 1})
    tmp = tempfile.mkdtemp(prefix="fleet_bridge_")
    try:
        trainer, state = build_trainer(
            get_smoke(arch), batch=batch, seq=seq, ckpt_dir=tmp,
            checkpoint_every=checkpoint_every, failures=dict(failures))
        # CPU timing jitter must not inject straggler idle events into
        # the measured structure
        trainer.straggler = StragglerPolicy(threshold=float("inf"))
        _, real_ledger, losses = trainer.run(state, steps)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    sim_ledger = simulate_trainer_plan(
        total_steps=steps, checkpoint_every=checkpoint_every,
        failures=failures)
    real_s, sim_s = real_ledger.structure(), sim_ledger.structure()
    return {
        "real_structure": real_s,
        "sim_structure": sim_s,
        "match": real_s == sim_s,
        "real_goodput": real_ledger.goodput,
        "sim_goodput": sim_ledger.goodput,
        "effective_steps": len(losses),
        "replay_summary": trainer.replay_summary(),
    }
