"""Validate the fleet simulator against the real resilient trainer.

``run_bridge`` executes the same failure plan twice:

  * for real — a smoke-scale ``ResilientTrainer`` run (actual model,
    actual checkpoints, actual OCS substitutions, measured seconds);
  * in the simulator — one fleet job with the identical plan
    (checkpoint cadence, failure steps, cube ids) and modeled seconds.

The two goodput ledgers must agree *event-for-event in structure*
(``GoodputLedger.structure()``: the merged (kind, steps) sequence —
bootstrap idle, step runs, checkpoint marks, detect/restore/rework
triplets with identical rework step counts). Durations differ by
construction (measured vs modeled); the grammar must not.

``GRAMMAR_KINDS`` is the pinned vocabulary both sides speak. Elastic
re-scale and synchronous checkpoint writes extend the *simulator's*
story, but every new charge stays inside this vocabulary (re-scale
markers are ``idle``, write stalls are ``idle``, re-scale restores are
``restore``/``rework``) — ``grammar_ok`` asserts exactly that, so the
bridge contract survives the elastic arm unchanged.
"""

from __future__ import annotations

import shutil
import tempfile
from typing import Dict, Optional

from repro.core.goodput import GoodputLedger
from repro.fleet.jobs import JobSpec
from repro.fleet.sim import FleetConfig, FleetSimulator

# The pinned ledger vocabulary: every event either side ever records.
GRAMMAR_KINDS = ("steps", "rework", "detect", "restore", "idle")


def grammar_ok(ledger: GoodputLedger) -> bool:
    """True iff every ledger event speaks the pinned five-kind grammar."""
    return all(e.kind in GRAMMAR_KINDS for e in ledger.events)

# Mirrors launch.train.build_trainer's pod: Ironwood-scale cube count,
# one 8192-chip job (cubes 0..127), 16 spares.
_TOTAL_CUBES = 144
_JOB_CHIPS = 128 * 64


def simulate_trainer_plan(
    *,
    total_steps: int,
    checkpoint_every: int,
    failures: Dict[int, int],
    step_time_s: float = 1.0,
    detect_s: float = 0.05,
    restore_s: float = 0.05,
    tpu: str = "ironwood",
) -> GoodputLedger:
    """Run the fleet simulator over the exact failure plan a
    ResilientTrainer would be given, returning the simulated ledger."""
    spec = JobSpec(
        name="train", chips=_JOB_CHIPS, total_steps=total_steps,
        step_time_s=step_time_s,
        checkpoint_every_steps=checkpoint_every,
        failure_steps=tuple(sorted(failures.items())))
    cfg = FleetConfig(tpu=tpu, total_cubes=_TOTAL_CUBES,
                      host_mtbf_hours=None, detect_s=detect_s,
                      restore_s=restore_s, reconfig_s=0.0, sdc=None)
    sim = FleetSimulator(cfg, [spec])
    # horizon: each failure costs detect + restore + rework, and rework
    # is bounded by the full history (checkpoint_every > total_steps)
    sim.run((1 + len(failures)) * total_steps * step_time_s
            + len(failures) * (detect_s + restore_s) + 1.0)
    job = sim.jobs["train"]
    assert job.state == "done", f"sim job did not finish: {job.state}"
    return job.ledger


def run_bridge(
    *,
    arch: str = "qwen2_0_5b",
    steps: int = 18,
    checkpoint_every: int = 6,
    failures: Optional[Dict[int, int]] = None,
    batch: int = 2,
    seq: int = 16,
) -> Dict[str, object]:
    """Real run vs simulated run of one failure plan; returns both
    structures, both goodputs, and whether the structures match."""
    from repro.configs.registry import get_smoke
    from repro.launch.train import build_trainer
    from repro.resilience.driver import StragglerPolicy

    failures = dict(failures if failures is not None else {9: 0, 14: 1})
    tmp = tempfile.mkdtemp(prefix="fleet_bridge_")
    try:
        trainer, state = build_trainer(
            get_smoke(arch), batch=batch, seq=seq, ckpt_dir=tmp,
            checkpoint_every=checkpoint_every, failures=dict(failures))
        # CPU timing jitter must not inject straggler idle events into
        # the measured structure
        trainer.straggler = StragglerPolicy(threshold=float("inf"))
        _, real_ledger, losses = trainer.run(state, steps)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    sim_ledger = simulate_trainer_plan(
        total_steps=steps, checkpoint_every=checkpoint_every,
        failures=failures)
    real_s, sim_s = real_ledger.structure(), sim_ledger.structure()
    return {
        "real_structure": real_s,
        "sim_structure": sim_s,
        "match": real_s == sim_s,
        "real_goodput": real_ledger.goodput,
        "sim_goodput": sim_ledger.goodput,
        "effective_steps": len(losses),
        "replay_summary": trainer.replay_summary(),
    }
