"""Job model for the fleet simulator.

A job is a synchronous training run on a slice of cubes: progress is
step-quantized (``step_time_s`` per step), checkpoints land at absolute
step multiples of ``checkpoint_every_steps`` (asynchronous writes — they
cost rework exposure, not step time, matching the repo's
``CheckpointManager``), and every interruption charges the job's
``GoodputLedger`` with the same event grammar the real
``ResilientTrainer`` produces: ``detect -> restore -> rework`` after a
failure, ``idle`` markers for checkpoint snapshots and queue waits. The
fleet bridge (fleet/bridge.py) pins that grammar against a real run.

Also here: the checkpoint-interval policy math — the Young/Daly
closed form and a direct search over ``core.goodput.modeled_goodput``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core.goodput import GoodputLedger, modeled_goodput
from repro.core.ocs import SliceAllocation


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One training job submitted to the fleet.

    ``failure_steps`` is the deterministic failure plan (step -> cube id,
    the same shape ``resilience.driver.FailurePlan`` takes; cube -1 means
    "any cube the job owns") used by the sim-vs-trainer bridge and by
    reproducible scenarios. Stochastic failures come from the fleet
    config instead.
    """

    name: str
    chips: int
    total_steps: int
    step_time_s: float = 1.0
    checkpoint_every_steps: int = 100
    arrival_s: float = 0.0
    failure_steps: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self) -> None:
        if self.total_steps <= 0:
            raise ValueError("total_steps must be positive")
        if self.checkpoint_every_steps <= 0:
            raise ValueError("checkpoint_every_steps must be positive")
        if self.step_time_s <= 0:
            raise ValueError("step_time_s must be positive")

    def plan(self) -> Dict[int, int]:
        return dict(self.failure_steps)


@dataclasses.dataclass
class JobRuntime:
    """Simulator-side mutable state of one job."""

    spec: JobSpec
    ledger: GoodputLedger = dataclasses.field(default_factory=GoodputLedger)
    state: str = "pending"  # pending|queued|running|starved|done
    alloc: Optional[SliceAllocation] = None
    base_step: int = 0  # progress at segment start
    last_ckpt_step: int = 0
    segment_start: float = 0.0  # sim time productive stepping (re)starts
    epoch: int = 0  # bumps whenever the timeline is rescheduled
    queued_since: float = 0.0
    pending_resume_step: Optional[int] = None  # progress before starvation
    sdc_corrupt_step: Optional[int] = None
    completed_at: Optional[float] = None
    plan: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.plan = self.spec.plan()

    def steps_at(self, t: float) -> int:
        """Step count reached by sim time ``t`` in the current segment
        (clamped: restore/rework windows put segment_start in the
        future)."""
        if self.state != "running":
            return self.base_step
        done = int(max(0.0, t - self.segment_start) // self.spec.step_time_s)
        return min(self.spec.total_steps, self.base_step + done)

    def next_planned_failure(self) -> Optional[Tuple[int, int]]:
        """(step, cube) of the earliest planned failure not yet fired."""
        if not self.plan:
            return None
        step = min(self.plan)
        return step, self.plan[step]

    @property
    def goodput(self) -> float:
        return self.ledger.goodput


# ---------------------------------------------------------------------------
# Checkpoint-interval policy.
# ---------------------------------------------------------------------------


def optimal_checkpoint_interval_s(mtbf_s: float,
                                  checkpoint_write_s: float) -> float:
    """Young/Daly first-order optimum: T* = sqrt(2 * delta * MTBF)."""
    if mtbf_s <= 0 or checkpoint_write_s <= 0:
        raise ValueError("mtbf and checkpoint write cost must be positive")
    return math.sqrt(2.0 * checkpoint_write_s * mtbf_s)


def search_checkpoint_interval(
    *,
    mtbf_hours: float,
    detect_s: float,
    restore_s: float,
    checkpoint_write_s: float,
    lo_s: float = 10.0,
    hi_s: float = 24 * 3600.0,
    points: int = 400,
) -> Tuple[float, float]:
    """Grid-search the interval maximizing ``modeled_goodput`` (log-spaced
    grid). Returns (best_interval_s, best_goodput). Agrees with Young/Daly
    to first order when detect/restore costs are small vs MTBF."""
    best_t, best_g = lo_s, -1.0
    for i in range(points):
        t = lo_s * (hi_s / lo_s) ** (i / (points - 1))
        g = modeled_goodput(mtbf_hours=mtbf_hours, detect_s=detect_s,
                            restore_s=restore_s, checkpoint_interval_s=t,
                            checkpoint_write_s=checkpoint_write_s)
        if g > best_g:
            best_t, best_g = t, g
    return best_t, best_g
