"""Job model for the fleet simulator.

A job is a synchronous training run on a slice of cubes: progress is
step-quantized (``step_time_s`` per step), checkpoints land at absolute
step multiples of ``checkpoint_every_steps`` (asynchronous writes — they
cost rework exposure, not step time, matching the repo's
``CheckpointManager`` — unless the fleet config prices synchronous
writes), and every interruption charges the job's ``GoodputLedger`` with
the same event grammar the real ``ResilientTrainer`` produces:
``detect -> restore -> rework`` after a failure, ``idle`` markers for
checkpoint snapshots and queue waits. The fleet bridge (fleet/bridge.py)
pins that grammar against a real run.

Elastic re-scale (the paper's "rescheduled at smaller scale" arm) is a
per-job policy: ``scale_policy="shrink"`` lets a starved job run on the
largest schedulable slice at or above ``min_cubes`` instead of queueing,
with ``step_time_for`` supplying the slice-size -> step-time curve
(roofline-fed via ``fleet.perf.StepTimeModel``, or ideal-linear when no
model is attached).

Also here: the checkpoint-interval policy math — the Young/Daly
closed form and a direct search over ``core.goodput.modeled_goodput``
(``fleet.perf.sim_checkpoint_interval_sweep`` validates the latter
against the simulator itself).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

from repro.core.goodput import GoodputLedger, modeled_goodput
from repro.core.ocs import SliceAllocation
from repro.core.topology import CUBE

SCALE_POLICIES = ("queue", "shrink")


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One training job submitted to the fleet.

    ``failure_steps`` is the deterministic failure plan (step -> cube id,
    the same shape ``resilience.driver.FailurePlan`` takes; cube -1 means
    "any cube the job owns") used by the sim-vs-trainer bridge and by
    reproducible scenarios. Stochastic failures come from the fleet
    config instead.

    ``scale_policy`` decides what starvation does: ``"queue"`` (default,
    the pre-elastic behavior — release the slice and wait for repairs)
    or ``"shrink"`` (run on the largest schedulable slice >= ``min_cubes``
    and grow back opportunistically). ``step_time_model`` maps a cube
    count to seconds per step (see ``fleet.perf``); without one, shrunken
    slices scale ideal-linearly from ``step_time_s``.
    """

    name: str
    chips: int
    total_steps: int
    step_time_s: float = 1.0
    checkpoint_every_steps: int = 100
    arrival_s: float = 0.0
    failure_steps: Tuple[Tuple[int, int], ...] = ()
    scale_policy: str = "queue"
    min_cubes: int = 0  # 0: full size only (with "shrink", defaults to 1)
    step_time_model: Optional[Callable[[int], float]] = None

    def __post_init__(self) -> None:
        if self.total_steps <= 0:
            raise ValueError("total_steps must be positive")
        if self.checkpoint_every_steps <= 0:
            raise ValueError("checkpoint_every_steps must be positive")
        if self.step_time_s <= 0:
            raise ValueError("step_time_s must be positive")
        if self.scale_policy not in SCALE_POLICIES:
            raise ValueError(f"scale_policy must be one of {SCALE_POLICIES}")
        if self.min_cubes < 0 or self.min_cubes > self.full_cubes:
            raise ValueError("min_cubes must be in [0, full_cubes]")
        if self.scale_policy == "shrink" and self.min_cubes == 0:
            object.__setattr__(self, "min_cubes", 1)

    @property
    def full_cubes(self) -> int:
        """Slice size, in cubes, of the job at its requested scale."""
        return CUBE.cubes_for(self.chips)

    @property
    def elastic(self) -> bool:
        return self.scale_policy == "shrink"

    def step_time_for(self, cubes: int) -> float:
        """Seconds per step on a slice of ``cubes`` cubes.

        With a roofline-fed model attached, the model answers (and also
        owns the full-size number); otherwise scale ideal-linearly from
        the declared full-size ``step_time_s`` — fixed global batch, so
        half the chips take twice as long."""
        if cubes <= 0:
            raise ValueError("cubes must be positive")
        if self.step_time_model is not None:
            return float(self.step_time_model(cubes))
        return self.step_time_s * self.full_cubes / cubes

    def plan(self) -> Dict[int, int]:
        return dict(self.failure_steps)


@dataclasses.dataclass
class JobRuntime:
    """Simulator-side mutable state of one job.

    ``cubes``/``step_time_s`` are the *current* slice size and speed —
    they diverge from the spec while an elastic job runs shrunken.
    ``ckpt_write_end``/``ckpt_write_step`` track an in-flight synchronous
    checkpoint write: the snapshot only becomes durable (and
    ``last_ckpt_step`` only advances) once the write completes, so a
    failure mid-write rolls back to the previous snapshot."""

    spec: JobSpec
    ledger: GoodputLedger = dataclasses.field(default_factory=GoodputLedger)
    state: str = "pending"  # pending|queued|running|starved|done
    alloc: Optional[SliceAllocation] = None
    base_step: int = 0  # progress at segment start
    last_ckpt_step: int = 0
    segment_start: float = 0.0  # sim time productive stepping (re)starts
    epoch: int = 0  # bumps whenever the timeline is rescheduled
    queued_since: float = 0.0
    pending_resume_step: Optional[int] = None  # progress before starvation
    sdc_corrupt_step: Optional[int] = None
    completed_at: Optional[float] = None
    first_admitted_at: Optional[float] = None
    plan: Dict[int, int] = dataclasses.field(default_factory=dict)
    cubes: int = 0  # current slice size (0 until first admitted)
    step_time_s: float = 0.0  # current seconds/step at the current size
    rescales: int = 0  # shrink events (starvation absorbed elastically)
    grow_backs: int = 0  # opportunistic re-expansions after repairs
    ckpt_write_end: Optional[float] = None  # sync write in flight until t
    ckpt_write_step: int = 0  # step the in-flight write snapshots

    def __post_init__(self) -> None:
        self.plan = self.spec.plan()
        self.step_time_s = self.spec.step_time_s

    @property
    def shrunken(self) -> bool:
        return self.state == "running" and 0 < self.cubes < \
            self.spec.full_cubes

    def set_scale(self, cubes: int) -> None:
        """Adopt a slice size: the step time follows the job's scaling
        curve (roofline-fed or ideal-linear)."""
        self.cubes = cubes
        self.step_time_s = self.spec.step_time_for(cubes)

    def steps_at(self, t: float) -> int:
        """Step count reached by sim time ``t`` in the current segment
        (clamped: restore/rework windows put segment_start in the
        future)."""
        if self.state != "running":
            return self.base_step
        done = int(max(0.0, t - self.segment_start) // self.step_time_s)
        return min(self.spec.total_steps, self.base_step + done)

    def next_planned_failure(self) -> Optional[Tuple[int, int]]:
        """(step, cube) of the earliest planned failure not yet fired."""
        if not self.plan:
            return None
        step = min(self.plan)
        return step, self.plan[step]

    @property
    def goodput(self) -> float:
        return self.ledger.goodput


# ---------------------------------------------------------------------------
# Checkpoint-interval policy.
# ---------------------------------------------------------------------------


def optimal_checkpoint_interval_s(mtbf_s: float,
                                  checkpoint_write_s: float) -> float:
    """Young/Daly first-order optimum: T* = sqrt(2 * delta * MTBF)."""
    if mtbf_s <= 0 or checkpoint_write_s <= 0:
        raise ValueError("mtbf and checkpoint write cost must be positive")
    return math.sqrt(2.0 * checkpoint_write_s * mtbf_s)


def search_checkpoint_interval(
    *,
    mtbf_hours: float,
    detect_s: float,
    restore_s: float,
    checkpoint_write_s: float,
    lo_s: float = 10.0,
    hi_s: float = 24 * 3600.0,
    points: int = 400,
) -> Tuple[float, float]:
    """Grid-search the interval maximizing ``modeled_goodput`` (log-spaced
    grid). Returns (best_interval_s, best_goodput). Agrees with Young/Daly
    to first order when detect/restore costs are small vs MTBF."""
    best_t, best_g = lo_s, -1.0
    for i in range(points):
        t = lo_s * (hi_s / lo_s) ** (i / (points - 1))
        g = modeled_goodput(mtbf_hours=mtbf_hours, detect_s=detect_s,
                            restore_s=restore_s, checkpoint_interval_s=t,
                            checkpoint_write_s=checkpoint_write_s)
        if g > best_g:
            best_t, best_g = t, g
    return best_t, best_g
