"""Discrete-event fleet simulator: many jobs, one pod, days of sim time.

The executable composition of the paper's resilience story:

  host/cube failures (Poisson per cube, scaled from per-host MTBF)
    -> detect -> OCS spare substitution via the *real* ``OCSPodScheduler``
    -> restore from the last checkpoint -> rework the lost steps
    -> per-job ``GoodputLedger`` charges, same event grammar as the real
       ``ResilientTrainer`` (fleet/bridge.py pins the agreement);

  silent data corruption (``core.sdc.SDCRateModel``)
    -> detected by a later sampled screen -> roll back to the last
       checkpoint *before the corruption* (later snapshots are poisoned)
    -> map out the offending cube;

  no spares -> the paper's two arms, selected per job by
  ``JobSpec.scale_policy``:

    * ``"queue"``  — the job releases its slice, queues, and is
      re-admitted (restore + rework) when a repair or completion frees
      cubes;
    * ``"shrink"`` — the job is *rescheduled at smaller scale*: it keeps
      running on the largest schedulable slice >= ``min_cubes``, its step
      time re-scaled by the job's slice-size curve (roofline-fed via
      ``fleet.perf``, or ideal-linear), and it grows back to full size
      opportunistically when repairs or completions free cubes. Every
      re-scale is ledgered inside the same five-kind grammar the bridge
      pins (an ``idle`` marker plus the usual restore/rework charges).

Checkpoint writes are free (asynchronous, the repo's
``CheckpointManager`` behavior) unless ``FleetConfig.ckpt_write_s`` is
set: then every snapshot stalls the job synchronously, concurrent
writers contend for the shared filer bandwidth (a write that starts
while k others are in flight takes (k+1)x the uncontended time), and a
snapshot only becomes durable when its write *completes* — a failure
mid-write rolls back to the previous checkpoint.

Serve jobs (``fleet.serve_jobs``) run alongside: open-loop request
arrivals (``serve_session``/``serve_req`` events) feed per-replica
queues whose service times come from a steptrace-calibrated
``ServiceTimeModel``; replicas are OCS allocations (``"job/rK"``) that
take cube failures like any training slice (substitution or teardown)
and autoscale against queue depth / SLO violations (``serve_ctl``),
contending with training jobs for cubes. Their ledgers speak the same
five-kind grammar — SLO-good busy time is ``steps``, violating busy
time is ``rework`` — so goodput and power/carbon pipelines need no new
vocabulary.

Progress is step-quantized but simulated analytically — between events a
job's step count is a closed-form function of time, so a week of
simulated pod time costs thousands of events, not billions of steps.
``contiguous=True`` runs the same fleet against pre-OCS (TPU v2/v3)
scheduling semantics: no substitution, rectangular-block allocation.
``install_schedule`` models incremental deployment (paper: each cube
enters production as soon as it is installed).

docs/fleet.md has the event-flow and elastic state diagrams, the module
map, and the table of paper anchors (``~97%``/``~93%`` goodput, Ironwood
4x2K-job spares, ``~29x`` CO2e per effective FLOP, the re-scale-vs-queue
goodput gap) that ``benchmarks/bench_fleet.py`` reproduces from this
simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import hwspec
from repro.core.ocs import OCSPodScheduler
from repro.core.sdc import SDCRateModel
from repro.core.topology import CUBE
from repro.fleet.events import Event, EventEngine
from repro.fleet.jobs import JobRuntime, JobSpec
from repro.fleet.serve_jobs import (ServeJobRuntime, ServeJobSpec,
                                    ServeReplica)
from repro.fleet.trace import TraceRecorder


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    tpu: str = "tpu_v4"
    total_cubes: int = 64
    host_mtbf_hours: Optional[float] = None  # None: planned failures only
    repair_hours: float = 4.0
    detect_s: float = 30.0
    restore_s: float = 120.0
    reconfig_s: float = 10.0  # OCS substitution latency, folded into restore
    ckpt_write_s: float = 0.0  # synchronous write stall; 0 = async writes
    sdc: Optional[SDCRateModel] = None
    contiguous: bool = False  # pre-OCS (TPU v2/v3) scheduling semantics
    # incremental deployment: (sim time, installed cube count) waypoints;
    # empty = the whole pod is installed from t=0
    install_schedule: Tuple[Tuple[float, int], ...] = ()
    seed: int = 0

    def __post_init__(self) -> None:
        if self.ckpt_write_s < 0.0:
            raise ValueError("ckpt_write_s must be >= 0")
        last_t, last_n = -1.0, 0
        for t, n in self.install_schedule:
            if t < 0.0 or t <= last_t:
                raise ValueError("install_schedule times must increase")
            if n < last_n or n > self.total_cubes:
                raise ValueError("install_schedule counts must be "
                                 "nondecreasing and <= total_cubes")
            last_t, last_n = t, n


class FleetSimulator:
    def __init__(self, cfg: FleetConfig, jobs: Sequence[JobSpec],
                 *, serve_jobs: Sequence[ServeJobSpec] = (), tracer=None):
        names = [j.name for j in jobs] + [s.name for s in serve_jobs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate job names")
        self.cfg = cfg
        self.spec = hwspec.get(cfg.tpu)
        self.engine = EventEngine(cfg.seed)
        self.sched = OCSPodScheduler(cfg.total_cubes,
                                     contiguous=cfg.contiguous)
        # pass a shared obs.trace.SpanTracer to land sim events in the
        # same timeline as serve/train spans (scripts/trace_gate.py)
        self.trace = TraceRecorder(tracer=tracer)
        self.jobs: Dict[str, JobRuntime] = {
            j.name: JobRuntime(spec=j) for j in jobs}
        self.serve: Dict[str, ServeJobRuntime] = {
            s.name: ServeJobRuntime(spec=s) for s in serve_jobs}
        self._replica_owner: Dict[str, str] = {}  # alloc name -> serve job
        self.stats = {"cube_failures": 0, "repairs": 0, "starvations": 0,
                      "rescales": 0, "grow_backs": 0,
                      "sdc_corruptions": 0, "sdc_detections": 0}
        self._fail_ev: Dict[int, Event] = {}
        self._writes: Dict[str, float] = {}  # job -> in-flight write end
        self._hosts_per_cube = max(1, CUBE.chips // self.spec.tpus_per_host)
        for j in jobs:
            self.engine.schedule_at(j.arrival_s, "arrival", job=j.name)
        for rt in self.serve.values():
            # a per-job RNG keyed on (fleet seed, job name) keeps the
            # request trace identical across autoscale policies and
            # independent of the failure draws below
            rt.seed_rng(cfg.seed)
            self.engine.schedule_at(rt.spec.arrival_s, "serve_live",
                                    job=rt.spec.name)
        if cfg.install_schedule:
            # nothing is installed until the first waypoint lands
            self.sched.set_installed(())
            for t, n in cfg.install_schedule:
                self.engine.schedule_at(t, "install", count=n)
        if cfg.host_mtbf_hours is not None:
            for cube in range(cfg.total_cubes):
                self._schedule_cube_failure(cube)

    # -------------------------------------------------------------- helpers

    @property
    def _cube_mtbf_s(self) -> float:
        assert self.cfg.host_mtbf_hours is not None
        return self.cfg.host_mtbf_hours * 3600.0 / self._hosts_per_cube

    def _schedule_cube_failure(self, cube: int) -> None:
        delay = self.engine.draw_exponential(self._cube_mtbf_s)
        self._fail_ev[cube] = self.engine.schedule(
            delay, "cube_fail", cube=cube)

    def _settle_ckpt(self, job: JobRuntime, t: float) -> None:
        """A synchronous snapshot becomes durable only once its write
        completes; settle the bookkeeping before anything reads
        ``last_ckpt_step`` at time ``t``."""
        if job.ckpt_write_end is not None and t >= job.ckpt_write_end:
            job.last_ckpt_step = job.ckpt_write_step
            job.ckpt_write_end = None

    def _start_write(self, job: JobRuntime, now: float) -> Tuple[float, int]:
        """Register a synchronous write against the shared filer: a write
        starting while k others are in flight takes (k+1)x the
        uncontended time (already-started writes keep their end times).
        Returns (stall seconds, concurrent writer count)."""
        name = job.spec.name
        self._writes = {j: t for j, t in self._writes.items()
                        if t > now and j != name}
        n = len(self._writes) + 1
        dur = self.cfg.ckpt_write_s * n
        self._writes[name] = now + dur
        return dur, n

    def _abort_write(self, job: JobRuntime) -> None:
        """An in-flight write dies with its slice (failure) or its
        snapshot (SDC poisoning): it must also stop occupying the shared
        filer bandwidth later writers contend for."""
        if job.ckpt_write_end is not None:
            self._writes.pop(job.spec.name, None)
            job.ckpt_write_end = None

    def _charge_progress(self, job: JobRuntime, target: int) -> None:
        """Record productive steps base_step..target on the ledger, with
        an idle checkpoint mark at every absolute boundary crossed —
        exactly the grammar the ResilientTrainer's main loop produces.
        Boundaries are strictly greater than base_step: a segment that
        starts at a restored step does not re-snapshot it.

        With synchronous writes (``ckpt_write_s > 0``) boundary marks are
        event-driven instead (``ckpt_write`` events re-segment the
        timeline at every boundary), so this only charges whole steps."""
        st = job.step_time_s
        every = job.spec.checkpoint_every_steps
        cur = job.base_step
        t0 = job.segment_start

        def run_steps(upto: int) -> None:
            nonlocal cur, t0
            k = upto - cur
            if k > 0:
                job.ledger.record_steps(k * st, steps=k)
                self.trace.duration(job.spec.name, "train", t0, k * st,
                                    args={"steps": f"{cur}..{upto}"})
                cur, t0 = upto, t0 + k * st

        if self.cfg.ckpt_write_s <= 0.0:
            next_b = (cur // every + 1) * every
            while next_b <= target:
                run_steps(next_b)
                job.ledger.record_idle(0.0, note=f"ckpt @{next_b}")
                self.trace.duration(job.spec.name, "ckpt", t0, 0.0,
                                    args={"step": next_b})
                job.last_ckpt_step = next_b
                next_b += every
        run_steps(target)
        job.base_step = cur
        job.segment_start = t0

    def _schedule_segment(self, job: JobRuntime) -> None:
        """(Re)issue the job's timeline events from the current segment.
        Bumps the epoch so events from the previous timeline are stale."""
        job.epoch += 1
        spec, e = job.spec, job.epoch
        st = job.step_time_s
        t_done = job.segment_start + (spec.total_steps - job.base_step) * st
        self.engine.schedule_at(t_done, "complete", job=spec.name, epoch=e)
        if self.cfg.ckpt_write_s > 0.0:
            every = spec.checkpoint_every_steps
            next_b = (job.base_step // every + 1) * every
            if next_b < spec.total_steps:
                t = job.segment_start + (next_b - job.base_step) * st
                self.engine.schedule_at(t, "ckpt_write", job=spec.name,
                                        step=next_b, epoch=e)
        planned = job.next_planned_failure()
        if planned is not None and planned[0] >= job.base_step:
            step, cube = planned
            t = job.segment_start + (step - job.base_step) * st
            self.engine.schedule_at(t, "plan_fail", job=spec.name,
                                    step=step, cube=cube, epoch=e)
        if self.cfg.sdc is not None:
            if job.sdc_corrupt_step is not None:
                # an undetected corruption survived a fail-stop restore
                # (the snapshot postdated it): re-arm its detection for
                # the new timeline
                delay = self.cfg.sdc.draw_detection_delay_s(
                    self.engine.rng)
                t = max(self.engine.now, job.segment_start) + delay
                self.engine.schedule_at(t, "sdc_detect", job=spec.name,
                                        epoch=e)
            else:
                dt = self.cfg.sdc.draw_time_to_corruption_s(
                    self.engine.rng, spec.chips)
                if dt != float("inf"):
                    t = max(self.engine.now, job.segment_start) + dt
                    self.engine.schedule_at(t, "sdc_corrupt",
                                            job=spec.name, epoch=e)

    # ------------------------------------------------------------ admission

    def _try_admit(self, job: JobRuntime) -> bool:
        now = self.engine.now
        spec = job.spec
        alloc = self.sched.allocate(spec.name, spec.chips)
        cubes = spec.full_cubes
        if alloc is None and spec.elastic:
            # elastic admission: take the largest schedulable slice at or
            # above the job's floor rather than waiting for full size
            n = self.sched.max_slice_cubes(spec.full_cubes - 1)
            if n >= spec.min_cubes:
                alloc = self.sched.allocate(spec.name, n * CUBE.chips)
                cubes = n
        if alloc is None:
            if job.state != "queued":
                job.state = "queued"
                job.queued_since = now
            return False
        job.alloc = alloc
        job.set_scale(cubes)
        if job.first_admitted_at is None:
            job.first_admitted_at = now
        wait = now - job.queued_since if job.state == "queued" else 0.0
        if wait > 0.0:
            job.ledger.record_idle(wait, note="queued for cubes")
            self.trace.duration(job.spec.name, "queued", now - wait, wait)
        if cubes < spec.full_cubes:
            job.rescales += 1
            self.stats["rescales"] += 1
            job.ledger.record_idle(
                0.0, note=f"re-scale to {cubes}/{spec.full_cubes} cubes")
            self.trace.instant("re-scale", now, {
                "job": spec.name, "cubes": f"{cubes}/{spec.full_cubes}"})
        st = job.step_time_s
        if job.pending_resume_step is None:
            # fresh start: the resilience contract's bootstrap snapshot
            job.ledger.record_idle(0.0, note="bootstrap ckpt")
            job.base_step = 0
            job.last_ckpt_step = 0
            job.segment_start = now
        else:
            rework = job.pending_resume_step - job.last_ckpt_step
            job.ledger.record_restore(self.cfg.restore_s,
                                      note="restore after starvation")
            job.ledger.record_rework(rework * st, steps=rework)
            self.trace.duration(job.spec.name, "restore", now,
                                self.cfg.restore_s)
            self.trace.duration(job.spec.name, "rework",
                                now + self.cfg.restore_s, rework * st)
            job.base_step = job.pending_resume_step
            job.segment_start = now + self.cfg.restore_s + rework * st
            job.pending_resume_step = None
        job.state = "running"
        self._schedule_segment(job)
        self.trace.counter("pod", now, {"spare_cubes":
                                        self.sched.spare_cubes()})
        return True

    def _admit_queued(self) -> None:
        queued = sorted((j for j in self.jobs.values()
                         if j.state == "queued"),
                        key=lambda j: (j.queued_since, j.spec.name))
        for job in queued:
            self._try_admit(job)

    def _try_grow(self) -> None:
        """Opportunistic grow-back (elastic jobs only): when capacity
        frees up — after queued jobs have had their chance — every job
        running shrunken tries to return to full size. Growth is
        all-or-nothing (partial regrows would pay the restart cost
        repeatedly) and graceful: snapshot the current step, re-shard
        across the grown slice (a restore charge), no rework."""
        shrunken = sorted((j for j in self.jobs.values() if j.shrunken),
                          key=lambda j: j.spec.name)
        for job in shrunken:
            now = self.engine.now
            spec = job.spec
            if job.ckpt_write_end is not None and job.ckpt_write_end > now:
                continue  # let the in-flight snapshot finish first
            grown = self.sched.grow(spec.name, spec.full_cubes - job.cubes)
            if grown is None:
                continue
            self._settle_ckpt(job, now)
            steps_now = job.steps_at(now)
            self._charge_progress(job, steps_now)
            # the in-flight step fraction is abandoned by the re-shard:
            # charge it with the snapshot (it is wall time already spent)
            # but only the write itself delays the new timeline
            partial = min(max(now - job.segment_start, 0.0),
                          job.step_time_s)
            prev = job.cubes
            job.alloc = grown
            job.set_scale(spec.full_cubes)
            job.grow_backs += 1
            self.stats["grow_backs"] += 1
            if self.cfg.ckpt_write_s > 0.0:
                # the pre-grow snapshot is a synchronous write like any
                # other: it contends for the filer and is durable only
                # once it completes
                write, _ = self._start_write(job, now)
                job.ckpt_write_end = now + write
                job.ckpt_write_step = steps_now
            else:
                write = 0.0
                job.last_ckpt_step = steps_now
            job.ledger.record_idle(write + partial,
                                   note=f"ckpt @{steps_now} (pre-grow)")
            restore = self.cfg.reconfig_s + self.cfg.restore_s
            job.ledger.record_idle(
                0.0, note=f"re-scale {prev}->{spec.full_cubes} cubes")
            job.ledger.record_restore(restore, note="grow-back restore")
            self.trace.instant("re-scale", now, {
                "job": spec.name,
                "cubes": f"{prev}->{spec.full_cubes}"})
            self.trace.duration(spec.name, "restore", now + write, restore)
            job.base_step = steps_now
            job.segment_start = now + write + restore
            self._schedule_segment(job)
            self.trace.counter("pod", now, {"spare_cubes":
                                            self.sched.spare_cubes()})

    # ------------------------------------------------------------- failures

    def _starve_or_shrink(self, job: JobRuntime, steps_now: int,
                          note: str) -> None:
        """No spares for a substitution. The queue arm releases the slice
        and waits; the elastic arm re-allocates the largest schedulable
        slice >= min_cubes right away and restores onto it (the paper's
        "rescheduled at smaller scale"). Both charge restore + rework
        exactly once — here for the shrink, at re-admission for the
        queue."""
        now = self.engine.now
        cfg = self.cfg
        spec = job.spec
        self.sched.release(spec.name)
        job.alloc = None
        if spec.elastic:
            n = self.sched.max_slice_cubes(spec.full_cubes)
            if n >= spec.min_cubes:
                prev = job.cubes
                alloc = self.sched.allocate(spec.name, n * CUBE.chips)
                assert alloc is not None and len(alloc.cubes) == n
                job.alloc = alloc
                job.set_scale(n)
                job.rescales += 1
                self.stats["rescales"] += 1
                st = job.step_time_s
                restore = cfg.reconfig_s + cfg.restore_s
                rework = steps_now - job.last_ckpt_step
                job.ledger.record_idle(
                    0.0, note=f"re-scale {prev}->{n} cubes")
                job.ledger.record_restore(restore,
                                          note=f"re-scale restore ({note})")
                job.ledger.record_rework(rework * st, steps=rework)
                t = now + cfg.detect_s
                self.trace.instant("re-scale", now, {
                    "job": spec.name, "cubes": f"{prev}->{n}"})
                self.trace.duration(spec.name, "restore", t, restore)
                self.trace.duration(spec.name, "rework", t + restore,
                                    rework * st)
                job.base_step = steps_now
                job.segment_start = t + restore + rework * st
                self._schedule_segment(job)
                self.trace.counter("pod", now, {"spare_cubes":
                                                self.sched.spare_cubes()})
                return
        # queue arm: only detection is on the books so far; restore +
        # rework are charged once, at re-admission. The queue clock
        # starts after the detection window so the charges never overlap.
        job.pending_resume_step = steps_now
        job.state = "queued"
        job.queued_since = now + cfg.detect_s
        job.epoch += 1  # timeline events are void
        self.stats["starvations"] += 1
        self.trace.instant("starved", now, {"job": spec.name})
        self._admit_queued()  # the freed cubes may fit a smaller job
        self._try_grow()  # ...or return a shrunken job to full size

    def _handle_job_failure(self, job: JobRuntime, cube: int,
                            note: str) -> None:
        now = self.engine.now
        cfg = self.cfg
        st = job.step_time_s
        self._settle_ckpt(job, now)
        steps_now = job.steps_at(now)
        self._charge_progress(job, steps_now)
        self._abort_write(job)  # a write in flight is lost with the slice
        # a stochastic failure lands mid-step: the aborted in-flight
        # fraction is wall time too, folded into the detection charge
        # (zero for planned failures, which fire on step boundaries)
        partial = min(max(now - job.segment_start, 0.0), st)
        job.ledger.record_detection(cfg.detect_s + partial, note=note)
        self.trace.duration(job.spec.name, "detect", now, cfg.detect_s)
        if job.sdc_corrupt_step is not None and \
                job.last_ckpt_step <= job.sdc_corrupt_step:
            # the fail-stop restore rolls back past the corruption point:
            # the corrupted state really is wiped. (A snapshot *after*
            # the corruption is poisoned — then the corruption survives
            # the restore and _schedule_segment re-arms its detection.)
            job.sdc_corrupt_step = None
        patched = self.sched.substitute(job.spec.name)
        if patched is None:
            # no spares (or pre-OCS pod): shrink or queue, per policy
            self._starve_or_shrink(job, steps_now, note)
            return
        job.alloc = patched
        restore = cfg.reconfig_s + cfg.restore_s
        rework = steps_now - job.last_ckpt_step
        job.ledger.record_restore(restore, note="ocs reconfig + restore")
        job.ledger.record_rework(rework * st, steps=rework)
        t = now + cfg.detect_s
        self.trace.duration(job.spec.name, "restore", t, restore)
        self.trace.duration(job.spec.name, "rework", t + restore,
                            rework * st)
        self.trace.instant("ocs_reconfig", now,
                           {"job": job.spec.name, "cube": cube})
        job.base_step = steps_now
        job.segment_start = t + restore + rework * st
        self._schedule_segment(job)

    # ------------------------------------------------------------ serve jobs

    def _serve_add_replica(self, rt: ServeJobRuntime) -> bool:
        """Allocate one more replica slice through the same OCS scheduler
        training jobs use — serve capacity *contends*. Returns False
        (and counts a blocked scale) when no slice fits."""
        now = self.engine.now
        spec = rt.spec
        idx = rt.next_replica
        name = f"{spec.name}/r{idx}"
        alloc = self.sched.allocate(name, spec.chips)
        if alloc is None:
            rt.scale_blocked += 1
            return False
        rt.next_replica += 1
        ready = now + spec.spinup_s
        rt.replicas[name] = ServeReplica(
            idx=idx, name=name, alloc=alloc, ready_at=ready, last_t=ready)
        self._replica_owner[name] = spec.name
        rt.peak_replicas = max(rt.peak_replicas, len(rt.replicas))
        if spec.spinup_s > 0:
            rt.ledger.record_restore(spec.spinup_s,
                                     note=f"{name} spin-up")
            self.trace.duration(name, "restore", now, spec.spinup_s)
        self.trace.instant("serve_scale", now, {
            "job": spec.name, "replica": name, "dir": "up"})
        self.engine.schedule_at(ready, "serve_ready", job=spec.name,
                                replica=name)
        return True

    def _serve_retire(self, rt: ServeJobRuntime, rep: ServeReplica) -> None:
        """Release a replica's slice back to the pod and give waiting
        training jobs their chance at the freed cubes."""
        rt.retire_replica(rep, self.engine.now)
        self.sched.release(rep.name)
        self._replica_owner.pop(rep.name, None)
        self._admit_queued()
        self._try_grow()

    def _serve_drain_queue(self, rt: ServeJobRuntime) -> None:
        now = self.engine.now
        while rt.queue:
            if rt.should_shed(rt.queue[0], now):
                req = rt.queue.pop(0)
                rt.shed_request(req)
                self.trace.instant("serve_shed", now, {
                    "job": rt.spec.name, "rid": req.rid})
                continue
            rep = rt.pick_replica(now)
            if rep is None:
                return
            self._serve_start(rt, rep, rt.queue.pop(0))

    def _serve_start(self, rt: ServeJobRuntime, rep: ServeReplica,
                     req) -> None:
        payload = rt.start_service(rep, req, self.engine.now)
        self.engine.schedule_at(float(payload["done"]), "serve_done",
                                **payload)

    def _handle_replica_failure(self, rt: ServeJobRuntime, repname: str,
                                cube: int, note: str) -> None:
        """A cube under a serve replica died. In-flight requests requeue
        (their arrival clocks keep running — the disruption lands in
        TTFT), then OCS substitution: a spare patches the slice and the
        replica reloads (detect + reconfig + restore, excluded from
        busy/idle); no spares tears the replica down — the control loop
        may re-add one later."""
        now = self.engine.now
        cfg = self.cfg
        rt.settle(now)
        rep = rt.replicas[repname]
        rt.requeue_inflight(rep)
        rt.ledger.record_detection(cfg.detect_s, note=note)
        self.trace.duration(repname, "detect", now, cfg.detect_s)
        patched = self.sched.substitute(repname)
        if patched is not None:
            restore = cfg.reconfig_s + cfg.restore_s
            rep.alloc = patched
            rep.ready_at = now + cfg.detect_s + restore
            rep.last_t = rep.ready_at
            rt.ledger.record_restore(restore,
                                     note="replica ocs reconfig + reload")
            self.trace.duration(repname, "restore", now + cfg.detect_s,
                                restore)
            self.engine.schedule_at(rep.ready_at, "serve_ready",
                                    job=rt.spec.name, replica=repname)
        else:
            rt.replicas_lost += 1
            self.trace.instant("serve_replica_lost", now, {
                "job": rt.spec.name, "replica": repname})
            self._serve_retire(rt, rep)
        self._serve_drain_queue(rt)

    def _route_failure(self, impacted: Optional[str], cube: int,
                       note: str) -> None:
        """Failures land on whoever owns the cube: a training job or a
        serve replica (allocation names ``job/rK``)."""
        if impacted is None:
            return
        owner = self._replica_owner.get(impacted)
        if owner is not None:
            self._handle_replica_failure(self.serve[owner], impacted,
                                         cube, note)
        else:
            self._handle_job_failure(self.jobs[impacted], cube, note=note)

    def _on_serve_live(self, ev: Event) -> None:
        rt = self.serve[ev["job"]]
        rt.state = "live"
        for _ in range(rt.spec.replicas):
            self._serve_add_replica(rt)
        self._schedule_next_session(rt, self.engine.now)
        self.engine.schedule(rt.spec.control_interval_s, "serve_ctl",
                             job=rt.spec.name)

    def _schedule_next_session(self, rt: ServeJobRuntime,
                               t: float) -> None:
        nxt = rt.draw_next_session_t(t)
        self.engine.schedule_at(nxt, "serve_session", job=rt.spec.name,
                                t=nxt)

    def _on_serve_session(self, ev: Event) -> None:
        rt = self.serve[ev["job"]]
        t0 = ev["t"]
        for req in rt.build_session(t0):
            self.engine.schedule_at(req.arrival_s, "serve_req",
                                    job=rt.spec.name, req=req)
        self._schedule_next_session(rt, t0)

    def _on_serve_req(self, ev: Event) -> None:
        rt = self.serve[ev["job"]]
        rt.arrived += 1
        rt.queue.append(ev["req"])  # FIFO through the central queue
        self._serve_drain_queue(rt)

    def _on_serve_done(self, ev: Event) -> None:
        rt = self.serve[ev["job"]]
        rep = rt.finish_service(ev.payload, self.engine.now)
        if rep is not None:
            self._serve_drain_queue(rt)

    def _on_serve_ready(self, ev: Event) -> None:
        rt = self.serve[ev["job"]]
        rep = rt.replicas.get(ev["replica"])
        if rep is None or rep.ready_at > self.engine.now:
            return  # torn down, or superseded by a failure re-arm
        self._serve_drain_queue(rt)

    def _on_serve_ctl(self, ev: Event) -> None:
        """Autoscale control tick: settle the ledger window, then act on
        queue depth / SLO violations (see ServeJobRuntime
        .scale_decision)."""
        rt = self.serve[ev["job"]]
        now = self.engine.now
        rt.settle(now)
        decision = rt.scale_decision(now)
        if decision == "up":
            if self._serve_add_replica(rt):
                rt.scale_ups += 1
        elif decision == "down":
            rep = rt.idle_replica(now)
            if rep is not None:
                rt.scale_downs += 1
                self.trace.instant("serve_scale", now, {
                    "job": rt.spec.name, "replica": rep.name,
                    "dir": "down"})
                self._serve_retire(rt, rep)
        rt.viol_since_tick = 0
        self.trace.counter(f"serve:{rt.spec.name}", now, {
            "replicas": float(len(rt.replicas)),
            "queue_depth": float(len(rt.queue))})
        self.engine.schedule(rt.spec.control_interval_s, "serve_ctl",
                             job=rt.spec.name)

    # -------------------------------------------------------------- handlers

    def _on_arrival(self, ev: Event) -> None:
        job = self.jobs[ev["job"]]
        job.queued_since = self.engine.now
        self._try_admit(job)

    def _on_complete(self, ev: Event) -> None:
        job = self.jobs[ev["job"]]
        if ev["epoch"] != job.epoch or job.state != "running":
            return
        self._charge_progress(job, job.spec.total_steps)
        job.state = "done"
        job.completed_at = self.engine.now
        self.sched.release(job.spec.name)
        job.alloc = None
        self.trace.instant("job_done", self.engine.now,
                           {"job": job.spec.name})
        self._admit_queued()
        self._try_grow()

    def _on_cube_fail(self, ev: Event) -> None:
        cube = ev["cube"]
        self._fail_ev.pop(cube, None)
        if cube in self.sched.failed_cubes:
            return  # already down (SDC map-out); repair will redraw
        self.stats["cube_failures"] += 1
        # the cube-level Poisson process aggregates its hosts' hazards;
        # pick which host actually died and map out through the
        # host-granular entry point (the paper's primary hazard)
        host = cube * self._hosts_per_cube + int(
            self.engine.rng.integers(self._hosts_per_cube))
        _, impacted = self.sched.fail_host(host, self.spec.tpus_per_host)
        self.trace.instant("cube_fail", self.engine.now,
                           {"cube": cube, "host": host})
        self.engine.schedule(self.cfg.repair_hours * 3600.0, "repair",
                             cube=cube)
        self._route_failure(impacted, cube, note=f"cube {cube} died")

    def _on_plan_fail(self, ev: Event) -> None:
        job = self.jobs[ev["job"]]
        if ev["epoch"] != job.epoch or job.state != "running":
            return
        step = ev["step"]
        job.plan.pop(step, None)
        cube = ev["cube"]
        if cube < 0:
            assert job.alloc is not None
            cube = job.alloc.cubes[0]
        self.stats["cube_failures"] += 1
        impacted = self.sched.fail_cube(cube)
        self.trace.instant("cube_fail", self.engine.now,
                           {"cube": cube, "planned_step": step})
        self.engine.schedule(self.cfg.repair_hours * 3600.0, "repair",
                             cube=cube)
        if impacted is not None and impacted != job.spec.name:
            # the planned cube belongs to another job (or a serve
            # replica): its owner takes a real failure; the planning job
            # still observes its planned interruption (driver semantics:
            # a planned failure always restores the planning job, owned
            # cube or not)
            self._route_failure(impacted, cube, note=f"cube {cube} died")
        self._handle_job_failure(job, cube, note=f"cube {cube} died")

    def _on_repair(self, ev: Event) -> None:
        cube = ev["cube"]
        self.sched.repair_cube(cube)
        self.stats["repairs"] += 1
        self.trace.instant("repair", self.engine.now, {"cube": cube})
        if self.cfg.host_mtbf_hours is not None and \
                cube not in self._fail_ev:
            self._schedule_cube_failure(cube)
        self._admit_queued()
        self._try_grow()

    def _on_install(self, ev: Event) -> None:
        """Incremental deployment waypoint: cubes 0..count-1 are now in
        production (paper: each cube is usable as soon as installed)."""
        count = ev["count"]
        self.sched.set_installed(range(count))
        self.trace.instant("install", self.engine.now, {"cubes": count})
        self.trace.counter("pod", self.engine.now,
                           {"installed_cubes": float(count)})
        self._admit_queued()
        self._try_grow()

    def _on_ckpt_write(self, ev: Event) -> None:
        """Synchronous checkpoint write at an absolute step boundary. The
        job stalls for the write; concurrent writers contend for the
        shared filer bandwidth (a write starting while k others are in
        flight takes (k+1)x the uncontended time — first-order fair
        share, already-started writes keep their end times). The snapshot
        becomes durable at write *completion* (see ``_settle_ckpt``)."""
        job = self.jobs[ev["job"]]
        if ev["epoch"] != job.epoch or job.state != "running":
            return
        now = self.engine.now
        self._settle_ckpt(job, now)
        step = ev["step"]
        self._charge_progress(job, step)
        dur, n = self._start_write(job, now)
        job.ledger.record_idle(
            dur, note=f"ckpt write @{step}"
            + (f" ({n} writers)" if n > 1 else ""))
        self.trace.duration(job.spec.name, "ckpt", now, dur,
                            args={"step": step, "writers": n})
        self.trace.counter("pod", now, {"ckpt_writers": float(n)})
        job.ckpt_write_end = now + dur
        job.ckpt_write_step = step
        job.segment_start = now + dur
        self._schedule_segment(job)

    def _on_sdc_corrupt(self, ev: Event) -> None:
        job = self.jobs[ev["job"]]
        if ev["epoch"] != job.epoch or job.state != "running":
            return
        assert self.cfg.sdc is not None
        corrupt_step = job.steps_at(self.engine.now)
        if corrupt_step >= job.spec.total_steps:
            return
        job.sdc_corrupt_step = corrupt_step
        self.stats["sdc_corruptions"] += 1
        delay = self.cfg.sdc.draw_detection_delay_s(self.engine.rng)
        self.engine.schedule(delay, "sdc_detect", job=job.spec.name,
                             epoch=job.epoch)
        self.trace.instant("sdc_corrupt", self.engine.now,
                           {"job": job.spec.name, "step": corrupt_step})

    def _on_sdc_detect(self, ev: Event) -> None:
        job = self.jobs[ev["job"]]
        if ev["epoch"] != job.epoch or job.state != "running" or \
                job.sdc_corrupt_step is None:
            # stale timeline: either a fail-stop restore wiped the
            # corrupted state (sdc_corrupt_step cleared) or the event was
            # superseded by a re-armed detection on a newer epoch
            return
        now = self.engine.now
        cfg = self.cfg
        st = job.step_time_s
        every = job.spec.checkpoint_every_steps
        self._settle_ckpt(job, now)
        steps_now = job.steps_at(now)
        self._charge_progress(job, steps_now)
        self._abort_write(job)  # an in-flight snapshot is poisoned too
        # every checkpoint since the corruption is poisoned: roll back to
        # the newest snapshot at or before the corruption step
        rollback = min(job.last_ckpt_step,
                       job.sdc_corrupt_step // every * every)
        partial = min(max(now - job.segment_start, 0.0), st)
        job.ledger.record_detection(cfg.detect_s + partial,
                                    note="sdc screen hit")
        self.stats["sdc_detections"] += 1
        self.trace.instant("sdc_detect", now, {
            "job": job.spec.name, "corrupt_step": job.sdc_corrupt_step,
            "rollback_to": rollback})
        self.trace.duration(job.spec.name, "detect", now, cfg.detect_s)
        job.sdc_corrupt_step = None
        job.last_ckpt_step = rollback
        # map out the defective cube, like FBIST screening would
        assert job.alloc is not None
        cube = job.alloc.cubes[0]
        pending = self._fail_ev.pop(cube, None)
        if pending is not None:
            self.engine.cancel(pending)
        self.sched.fail_cube(cube)
        self.engine.schedule(cfg.repair_hours * 3600.0, "repair", cube=cube)
        patched = self.sched.substitute(job.spec.name)
        if patched is None:
            # shrink or starve; restore + rework (from the rolled-back
            # snapshot) are charged by the shrink path now, or once at
            # re-admission for the queue arm
            self._starve_or_shrink(job, steps_now, note="sdc map-out")
            return
        job.alloc = patched
        restore = cfg.reconfig_s + cfg.restore_s
        rework = steps_now - rollback
        job.ledger.record_restore(restore, note="sdc rollback + map-out")
        job.ledger.record_rework(rework * st, steps=rework,
                                 note="sdc rework (poisoned ckpts)")
        self.trace.duration(job.spec.name, "restore", now + cfg.detect_s,
                            restore)
        self.trace.duration(job.spec.name, "rework",
                            now + cfg.detect_s + restore, rework * st)
        job.base_step = steps_now
        job.segment_start = now + cfg.detect_s + restore + rework * st
        self._schedule_segment(job)

    _HANDLERS = {
        "arrival": _on_arrival,
        "complete": _on_complete,
        "cube_fail": _on_cube_fail,
        "plan_fail": _on_plan_fail,
        "repair": _on_repair,
        "install": _on_install,
        "ckpt_write": _on_ckpt_write,
        "sdc_corrupt": _on_sdc_corrupt,
        "sdc_detect": _on_sdc_detect,
        "serve_live": _on_serve_live,
        "serve_session": _on_serve_session,
        "serve_req": _on_serve_req,
        "serve_done": _on_serve_done,
        "serve_ready": _on_serve_ready,
        "serve_ctl": _on_serve_ctl,
    }

    # ------------------------------------------------------------------ run

    def run(self, until_s: float, *, check_invariants: bool = True) -> None:
        """Advance simulated time to ``until_s``, then close the books:
        running jobs charge whole steps completed by the horizon so the
        per-job ledgers describe exactly the simulated window."""
        for ev in self.engine.drain_until(until_s):
            self._HANDLERS[ev.kind](self, ev)
            if check_invariants:
                self.sched.check_invariants()
        for job in self.jobs.values():
            if job.state == "running":
                self._charge_progress(job, job.steps_at(until_s))
            elif job.state == "queued":
                wait = until_s - job.queued_since
                if wait > 0.0:
                    job.ledger.record_idle(wait, note="queued for cubes")
                    job.queued_since = until_s
        for rt in self.serve.values():
            if rt.state == "live":
                rt.settle(until_s)

    # -------------------------------------------------------------- reports

    def summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for name, job in self.jobs.items():
            s = job.ledger.summary()
            s["state_done"] = float(job.state == "done")
            s["steps_done"] = float(job.base_step)
            s["cubes"] = float(job.cubes)
            s["rescales"] = float(job.rescales)
            s["grow_backs"] = float(job.grow_backs)
            out[name] = s
        for name, rt in self.serve.items():
            s = rt.ledger.summary()
            s.update(rt.slo_summary())  # key sets are disjoint
            out[name] = s
        return out

    def fleet_summary(self) -> Dict[str, float]:
        gp = [j.ledger.goodput for j in self.jobs.values()
              if j.ledger.total_seconds > 0]
        steps = sum(j.base_step for j in self.jobs.values())
        out = {
            **{k: float(v) for k, v in self.stats.items()},
            "ocs_reconfigs": float(self.sched.reconfig_count),
            "spare_cubes": float(self.sched.spare_cubes()),
            "events_processed": float(self.engine.processed),
            "jobs_done": float(sum(j.state == "done"
                                   for j in self.jobs.values())),
            "steps_done": float(steps),
            "min_goodput": min(gp) if gp else 1.0,
            "mean_goodput": sum(gp) / len(gp) if gp else 1.0,
        }
        if self.serve:
            good = sum(rt.good_tokens for rt in self.serve.values())
            total = sum(rt.total_tokens for rt in self.serve.values())
            out["serve_requests"] = float(sum(
                rt.arrived for rt in self.serve.values()))
            out["serve_finished"] = float(sum(
                rt.finished for rt in self.serve.values()))
            out["serve_slo_goodput"] = good / total if total else 1.0
            out["serve_scale_ups"] = float(sum(
                rt.scale_ups for rt in self.serve.values()))
            out["serve_scale_downs"] = float(sum(
                rt.scale_downs for rt in self.serve.values()))
            out["serve_scale_blocked"] = float(sum(
                rt.scale_blocked for rt in self.serve.values()))
            out["serve_replicas_lost"] = float(sum(
                rt.replicas_lost for rt in self.serve.values()))
        return out
