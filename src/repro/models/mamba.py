"""Mamba (selective SSM) layer — the attention-free sublayer of Jamba.

Training/prefill runs a chunked selective scan: ``lax.scan`` carries the
(B, d_inner, N) state across chunks; within a chunk the linear recurrence
h_t = dA_t * h_{t-1} + dB_t x_t is evaluated with ``associative_scan``
(work-efficient, parallel over time). Decode is the single-step recurrence
against cached (conv window, ssm state).

The inner width d_inner is tensor-parallel over "model" (each shard owns a
slice of channels; the recurrence is channel-local so no collectives are
needed inside the scan — only the in/out projections communicate), which is
exactly how the Megacore-style sharding applies to an attention-free arch.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec, constant_init, normal_init, \
    ones_init, uniform_init, zeros_init

Array = jax.Array


def fit_chunk(seq: int, chunk: int) -> int:
    """Largest divisor of ``seq`` that is <= ``chunk``."""
    c = max(1, min(chunk, seq))
    while seq % c:
        c -= 1
    return c


def mamba_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, n, dr, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state_dim,
                       cfg.dt_rank, cfg.ssm_conv_width)

    def a_log_init(key, shape, dtype):
        # S4D-real initialization: A = -(1..N) per channel
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                             shape)
        return jnp.log(a).astype(dtype)

    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((w, di), ("conv", "mlp"),
                            init=normal_init(0.1)),
        "conv_b": ParamSpec((di,), ("mlp",), init=zeros_init()),
        "x_proj": ParamSpec((di, dr + 2 * n), ("mlp", None)),
        "dt_proj": ParamSpec((dr, di), (None, "mlp"),
                             init=normal_init(dr ** -0.5)),
        "dt_bias": ParamSpec((di,), ("mlp",),
                             init=uniform_init(-4.6, -2.3)),  # softplus→dt
        "a_log": ParamSpec((di, n), ("mlp", "state"), init=a_log_init),
        "d_skip": ParamSpec((di,), ("mlp",), init=ones_init()),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _ssm_scan_chunked(dA: Array, dBx: Array, h0: Array,
                      chunk: int) -> Tuple[Array, Array]:
    """Linear recurrence h_t = dA_t*h_{t-1} + dBx_t over time axis 1.

    dA, dBx: (B, S, C, N). h0: (B, C, N). Returns (h_all (B,S,C,N), h_last).
    """
    b, s, c, n = dA.shape
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk
    dA_c = dA.reshape(b, nc, chunk, c, n)
    dBx_c = dBx.reshape(b, nc, chunk, c, n)

    def body(h, xs):
        a_ch, bx_ch = xs  # (B, chunk, C, N)
        # prefix: contribution of incoming state decayed through the chunk
        a_cum = jnp.cumprod(a_ch, axis=1)
        carry_in = a_cum * h[:, None]
        # intra-chunk recurrence via associative scan
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        _, h_intra = jax.lax.associative_scan(
            combine, (a_ch, bx_ch), axis=1)
        h_all = h_intra + carry_in
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(
        body, h0, (dA_c.transpose(1, 0, 2, 3, 4),
                   dBx_c.transpose(1, 0, 2, 3, 4)))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, c, n)
    return h_all, h_last


def mamba_forward(
    params: Dict[str, Array], x: Array, cfg: ModelConfig, compute_dtype,
    *,
    chunk: int = 256,
    init_state: Optional[Tuple[Array, Array]] = None,
    return_state: bool = False,
    seq_mask: Optional[Array] = None,
):
    """x: (B, S, D). Returns out (B,S,D) [, (conv_cache, ssm_state)].

    ``seq_mask`` (B,S) zeroes the post-conv activation at padded
    positions: with zero inputs the only nonzero intermediate is the conv
    bias, and masking it keeps dBx = 0 there, so a zero-initialized state
    passes through a pad *prefix* unchanged (front-padded bucketed
    prefill)."""
    b, s, d = x.shape
    di, n, dr, w = cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank, \
        cfg.ssm_conv_width

    xz = x @ params["in_proj"].astype(compute_dtype)  # (B,S,2di)
    xin, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time
    conv_cache_in = (init_state[0] if init_state is not None else
                     jnp.zeros((b, w - 1, di), compute_dtype))
    xpad = jnp.concatenate([conv_cache_in, xin], axis=1)  # (B, S+w-1, di)
    conv_w = params["conv_w"].astype(compute_dtype)  # (w, di)
    xc = sum(xpad[:, i:i + s, :] * conv_w[i] for i in range(w))
    xc = jax.nn.silu((xc + params["conv_b"].astype(compute_dtype))
                     .astype(jnp.float32)).astype(compute_dtype)
    if seq_mask is not None:
        xc = xc * seq_mask[..., None].astype(xc.dtype)
    new_conv_cache = xpad[:, s:, :]  # last w-1 inputs

    # input-dependent dt, B, C
    dbc = xc @ params["x_proj"].astype(compute_dtype)  # (B,S,dr+2N)
    dt_low, b_in, c_in = jnp.split(dbc, [dr, dr + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_low @ params["dt_proj"].astype(compute_dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))  # (B,S,di) fp32
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, N)

    dA = jnp.exp(dt[..., None] * a)  # (B,S,di,N) fp32
    dBx = (dt * xc.astype(jnp.float32))[..., None] * \
        b_in.astype(jnp.float32)[..., None, :]  # (B,S,di,N)

    h0 = (init_state[1].astype(jnp.float32) if init_state is not None else
          jnp.zeros((b, di, n), jnp.float32))
    chunk = fit_chunk(s, chunk)
    h_all, h_last = _ssm_scan_chunked(dA, dBx, h0, chunk)

    y = jnp.einsum("bscn,bsn->bsc", h_all,
                   c_in.astype(jnp.float32))  # (B,S,di)
    y = y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = y.astype(compute_dtype) * jax.nn.silu(
        z.astype(jnp.float32)).astype(compute_dtype)
    out = y @ params["out_proj"].astype(compute_dtype)
    if return_state:
        return out, (new_conv_cache, h_last.astype(jnp.float32))
    return out


def mamba_decode_step(
    params: Dict[str, Array], x: Array, state: Tuple[Array, Array],
    cfg: ModelConfig, compute_dtype,
) -> Tuple[Array, Tuple[Array, Array]]:
    """Single-token step. x: (B, 1, D); state: (conv (B,w-1,di), h (B,di,N))."""
    out, new_state = mamba_forward(
        params, x, cfg, compute_dtype, chunk=1, init_state=state,
        return_state=True)
    return out, new_state
