"""Encoder-decoder transformer (Whisper backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, encoder_seq, d_model) — what Whisper's two
conv layers would produce. Encoder: bidirectional attention + GELU MLP +
LayerNorm + learned positions. Decoder: causal self-attention + cross
attention over encoder states + GELU MLP.

Both stacks scan over stacked per-layer params; decode caches hold the
self-attention ring buffer plus the (static after prefill) cross-attention
k/v.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention, full_attention
from repro.models.blocks import ModelContext, _project_qkv, attn_param_specs
from repro.models.config import ModelConfig
from repro.models.moe import dense_ffn, dense_ffn_specs
from repro.models.ops import embed_lookup, layer_norm, softmax_cross_entropy
from repro.models.params import ParamSpec, normal_init, ones_init, zeros_init

Array = jax.Array

MAX_DEC_POSITIONS = 32768  # mechanical ceiling for the assigned shapes


def _ln_specs(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), init=ones_init()),
            "bias": ParamSpec((d,), ("embed",), init=zeros_init())}


def _ln(p, x, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def enc_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": _ln_specs(cfg.d_model), "attn": attn_param_specs(cfg),
            "ln2": _ln_specs(cfg.d_model), "mlp": dense_ffn_specs(cfg)}


def dec_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": _ln_specs(cfg.d_model), "attn": attn_param_specs(cfg),
            "lnx": _ln_specs(cfg.d_model), "xattn": attn_param_specs(cfg),
            "ln2": _ln_specs(cfg.d_model), "mlp": dense_ffn_specs(cfg)}


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.blocks import stack_specs
    d, v = cfg.d_model, cfg.vocab_size
    return {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "enc_pos": ParamSpec((cfg.encoder_seq, d), (None, "embed"),
                             init=normal_init(0.01)),
        "dec_pos": ParamSpec((MAX_DEC_POSITIONS, d), (None, "embed"),
                             init=normal_init(0.01)),
        "enc_blocks": stack_specs(enc_layer_specs(cfg), cfg.encoder_layers),
        "dec_blocks": stack_specs(dec_layer_specs(cfg), cfg.n_layers),
        "enc_norm": _ln_specs(d),
        "final_norm": _ln_specs(d),
        "lm_head": ParamSpec((d, v), ("embed", "vocab")),
    }


def _self_attn(p, x, cfg, ctx, attn_type):
    dtype = ctx.compute_dtype
    q, k, v = _project_qkv(p, x, cfg, dtype)
    out = full_attention(q, k, v, cfg, q_chunk=ctx.q_chunk,
                         attn_type=attn_type)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def _cross_attn(p, x, enc_kv, cfg, ctx):
    dtype = ctx.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
    k, v = enc_kv
    out = full_attention(q, k, v, cfg, q_chunk=ctx.q_chunk,
                         attn_type="bidirectional", window=None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def _enc_kv(p, enc_out, cfg, ctx):
    dtype = ctx.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return k, v


def encode(params, enc_feats: Array, cfg: ModelConfig,
           ctx: ModelContext) -> Array:
    x = enc_feats.astype(ctx.compute_dtype) + \
        params["enc_pos"].astype(ctx.compute_dtype)
    x = ctx.shard(x, ("batch", "act_seq", "embed"))

    def body(x, lp):
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        x = x + _self_attn(lp["attn"], h, cfg, ctx, "bidirectional")
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        x = x + dense_ffn(lp["mlp"], h, cfg, ctx.compute_dtype)
        x = ctx.shard(x, ("batch", "act_seq", "embed"))
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
    return _ln(params["enc_norm"], x, cfg.norm_eps)


def encdec_loss(params, batch, cfg: ModelConfig, ctx: ModelContext
                ) -> Tuple[Array, Dict[str, Array]]:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    enc_out = encode(params, batch["enc_feats"], cfg, ctx)
    x = embed_lookup(params["embed"], tokens, ctx.compute_dtype)
    x = x + params["dec_pos"][:s].astype(ctx.compute_dtype)
    x = ctx.shard(x, ("batch", "act_seq", "embed"))

    def body(x, lp):
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        x = x + _self_attn(lp["attn"], h, cfg, ctx, "causal")
        h = _ln(lp["lnx"], x, cfg.norm_eps)
        x = x + _cross_attn(lp["xattn"], h,
                            _enc_kv(lp["xattn"], enc_out, cfg, ctx),
                            cfg, ctx)
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        x = x + dense_ffn(lp["mlp"], h, cfg, ctx.compute_dtype)
        x = ctx.shard(x, ("batch", "act_seq", "embed"))
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_blocks"])
    x = _ln(params["final_norm"], x, cfg.norm_eps)
    logits = ctx.shard(x @ params["lm_head"].astype(ctx.compute_dtype),
                       ("batch", "seq", "vocab"))
    loss, count = softmax_cross_entropy(logits, labels,
                                        batch.get("loss_mask"))
    return loss, {"xent": loss, "loss": loss, "tokens": count}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def encdec_cache_spec(cfg: ModelConfig, batch: int, window: int,
                      ctx: ModelContext) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    cdt = ctx.cache_dtype
    per_layer = {
        "k": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, window, cfg.n_kv_heads, hd), cdt),
        "v": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, window, cfg.n_kv_heads, hd), cdt),
        "xk": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), cdt),
        "xv": jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), cdt),
    }
    return {"blocks": per_layer,
            "pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}


def encdec_prefill(params, batch, cfg: ModelConfig, ctx: ModelContext,
                   window: int):
    """Encode audio, prefill decoder tokens. Returns (logits, cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc_out = encode(params, batch["enc_feats"], cfg, ctx)
    x = embed_lookup(params["embed"], tokens, ctx.compute_dtype)
    x = x + params["dec_pos"][:s].astype(ctx.compute_dtype)
    x = ctx.shard(x, ("batch", "act_seq", "embed"))

    def body(x, lp):
        dtype = ctx.compute_dtype
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], h, cfg, dtype)
        out = full_attention(q, k, v, cfg, q_chunk=ctx.q_chunk,
                             attn_type="causal", window=None)
        x = x + jnp.einsum("bshk,hkd->bsd", out,
                           lp["attn"]["wo"].astype(dtype))
        h = _ln(lp["lnx"], x, cfg.norm_eps)
        xk, xv = _enc_kv(lp["xattn"], enc_out, cfg, ctx)
        x = x + _cross_attn(lp["xattn"], h, (xk, xv), cfg, ctx)
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        x = x + dense_ffn(lp["mlp"], h, cfg, dtype)
        x = ctx.shard(x, ("batch", "act_seq", "embed"))
        w = window
        kk = jnp.zeros((b, w, cfg.n_kv_heads, cfg.resolved_head_dim),
                       ctx.cache_dtype)
        vv = jnp.zeros_like(kk)
        take = min(w, s)
        kk = kk.at[:, :take].set(k[:, s - take:].astype(ctx.cache_dtype))
        vv = vv.at[:, :take].set(v[:, s - take:].astype(ctx.cache_dtype))
        cache = {"k": kk, "v": vv, "xk": xk.astype(ctx.cache_dtype),
                 "xv": xv.astype(ctx.cache_dtype)}
        return x, cache

    x, caches = jax.lax.scan(jax.checkpoint(body), x, params["dec_blocks"])
    x = _ln(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(ctx.compute_dtype)
    pos = jnp.full((b,), s, jnp.int32)
    return logits, {"blocks": caches, "pos": pos}


def encdec_decode_step(params, token, cache, cfg: ModelConfig,
                       ctx: ModelContext):
    pos = cache["pos"]
    b = token.shape[0]
    dtype = ctx.compute_dtype
    x = embed_lookup(params["embed"], token, dtype)
    # per-request positions (continuous batching decodes mixed lengths)
    x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None].astype(dtype)

    def body(x, xs):
        lp, bc = xs
        h = _ln(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], h, cfg, dtype)
        w = bc["k"].shape[1]
        bidx = jnp.arange(b)
        slot = pos % w  # (B,)
        newk = bc["k"].at[bidx, slot].set(k[:, 0].astype(ctx.cache_dtype))
        newv = bc["v"].at[bidx, slot].set(v[:, 0].astype(ctx.cache_dtype))
        out = decode_attention(q, newk.astype(dtype), newv.astype(dtype),
                               pos + 1, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", out,
                           lp["attn"]["wo"].astype(dtype))
        h = _ln(lp["lnx"], x, cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"].astype(dtype))
        if cfg.qkv_bias:
            qx = qx + lp["xattn"]["bq"].astype(dtype)
        enc_len = bc["xk"].shape[1]
        xout = decode_attention(
            qx, bc["xk"].astype(dtype), bc["xv"].astype(dtype),
            jnp.full((b,), enc_len, jnp.int32), cfg, window=None)
        # cross attention attends to ALL encoder positions
        x = x + jnp.einsum("bshk,hkd->bsd", xout,
                           lp["xattn"]["wo"].astype(dtype))
        h = _ln(lp["ln2"], x, cfg.norm_eps)
        x = x + dense_ffn(lp["mlp"], h, cfg, dtype)
        return x, {"k": newk, "v": newv, "xk": bc["xk"], "xv": bc["xv"]}

    x, new_blocks = jax.lax.scan(body, x, (params["dec_blocks"],
                                           cache["blocks"]))
    x = _ln(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["lm_head"].astype(dtype)
    return logits, {"blocks": new_blocks, "pos": pos + 1}
