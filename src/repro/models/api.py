"""Family-agnostic model API: specs, loss, prefill, decode, input specs.

Everything downstream (trainer, server, dry-run, tests) goes through these
five functions; encoder-decoder vs decoder-only dispatch happens here.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig

Array = jax.Array


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.is_encoder_decoder:
        return encdec.encdec_specs(cfg)
    return lm.lm_specs(cfg)


def loss_fn(params, batch, cfg: ModelConfig, ctx: ModelContext):
    if cfg.is_encoder_decoder:
        return encdec.encdec_loss(params, batch, cfg, ctx)
    return lm.lm_loss(params, batch, cfg, ctx)


def cache_spec(cfg: ModelConfig, batch: int, window: int,
               ctx: ModelContext) -> Dict[str, Any]:
    if cfg.is_encoder_decoder:
        return encdec.encdec_cache_spec(cfg, batch, window, ctx)
    return lm.lm_cache_spec(cfg, batch, window, ctx)


def prefill_fn(params, batch, cfg: ModelConfig, ctx: ModelContext,
               window: int, logits_at=None, pad_left=None):
    """``logits_at`` (B,): index of the position whose logits to return
    (decoder-only; lets servers pad prompts to one compile length).
    ``pad_left`` (B,): leading pad count for front-padded state-family
    prompts (see lm_prefill). ``batch["positions"]`` (3,B,S) explicit
    mrope rows are honored exactly as the training loss honors them."""
    if cfg.is_encoder_decoder:
        if logits_at is not None or pad_left is not None:
            raise NotImplementedError(
                "logits_at/pad_left require a decoder-only model")
        return encdec.encdec_prefill(params, batch, cfg, ctx, window)
    return lm.lm_prefill(params, batch["tokens"], cfg, ctx, window,
                         logits_at=logits_at, pad_left=pad_left,
                         mrope_positions=batch.get("positions"))


def decode_fn(params, token, cache, cfg: ModelConfig, ctx: ModelContext):
    if cfg.is_encoder_decoder:
        return encdec.encdec_decode_step(params, token, cache, cfg, ctx)
    return lm.lm_decode_step(params, token, cache, cfg, ctx)


def decode_span_fn(params, tokens, cache, cfg: ModelConfig,
                   ctx: ModelContext, logits_at=None,
                   mrope_positions=None):
    """T-token span decode against dense per-slot caches — the
    chunked-prefill datapath for hybrid (attention + state) stacks.
    ``cache["pos"]`` may be negative: positions < 0 are the dead front
    padding of a right-aligned first chunk (see lm.lm_decode_span).
    ``logits_at`` (B,) gathers one position's logits before the lm head.
    ``mrope_positions`` (3,B,T) carries explicit multimodal rope rows for
    the span (None = text default)."""
    if cfg.is_encoder_decoder:
        raise ValueError(f"{cfg.name}: span decode requires decoder-only")
    return lm.lm_decode_span(params, tokens, cache, cfg, ctx,
                             logits_at=logits_at,
                             mrope_positions=mrope_positions)


def supports_paged_decode(cfg: ModelConfig) -> bool:
    """Paged KV applies to pure-attention decoder-only stacks; SSM/RWKV
    sublayers carry O(1) state and encoder-decoder keeps cross-KV."""
    return (not cfg.is_encoder_decoder
            and set(cfg.sublayer_kinds()) == {"attn"})


def paged_state_spec(cfg: ModelConfig, num_pages: int, page_size: int,
                     max_batch: int, max_pages_per_seq: int,
                     ctx: ModelContext) -> Dict[str, Any]:
    if not supports_paged_decode(cfg):
        raise ValueError(f"{cfg.name}: no paged decode for this family")
    return lm.lm_paged_state_spec(cfg, num_pages, page_size, max_batch,
                                  max_pages_per_seq, ctx)


def decode_paged_fn(params, token, state, cfg: ModelConfig,
                    ctx: ModelContext):
    """One decode step against the paged KV pool (see blocks.py)."""
    if not supports_paged_decode(cfg):
        raise ValueError(f"{cfg.name}: no paged decode for this family")
    return lm.lm_decode_step_paged(params, token, state, cfg, ctx)


def decode_span_paged_fn(params, tokens, state, cfg: ModelConfig,
                         ctx: ModelContext, valid=None, logits_at=None,
                         mrope_positions=None):
    """T-token span decode against the paged pool: one batched paged-
    attention call scores T consecutive tokens per request (speculative
    draft-verify; suffix/chunked prefill). ``logits_at`` (B,) gathers a
    single position's logits before the lm head (prefill chunks);
    ``mrope_positions`` (3,B,T) carries explicit multimodal rope rows
    (None = text default); ``pos`` in the returned state is unchanged —
    the caller owns acceptance/rollback (see lm.lm_decode_span_paged)."""
    if not supports_paged_decode(cfg):
        raise ValueError(f"{cfg.name}: no paged decode for this family")
    return lm.lm_decode_span_paged(params, tokens, state, cfg, ctx,
                                   valid=valid, logits_at=logits_at,
                                   mrope_positions=mrope_positions)


def train_batch_specs(cfg: ModelConfig, batch: int,
                      seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        specs["enc_feats"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.pos_emb == "mrope":
        specs["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return specs


BATCH_LOGICAL = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "enc_feats": ("batch", None, "embed"),
    "positions": (None, "batch", "seq"),
}
