"""GQA attention: training/prefill (full-sequence, q-chunked) and decode.

Implementations are selectable (``impl``):
  "xla"     — pure jnp, exact, q-chunked so the score matrix never exceeds
              (chunk x S) per head; the dry-run path (clean HLO).
  "pallas"  — flash-attention Pallas kernel (TPU target; interpret=True on
              CPU), used by tests/benchmarks via kernels/ops.py.

Masks are computed from positions, never materialized at (S x S) outside the
chunk: causal, sliding-window (Mixtral), bidirectional (Whisper encoder),
and decode (cache validity window).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ops import apply_mrope, apply_rope

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def _positions(batch_shape, seq: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(seq), (*batch_shape, seq))


def apply_positional(q: jax.Array, k: jax.Array, cfg: ModelConfig,
                     positions: jax.Array,
                     mrope_positions: Optional[jax.Array]) -> Tuple[
                         jax.Array, jax.Array]:
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_emb == "mrope":
        mp = (mrope_positions if mrope_positions is not None
              else jnp.broadcast_to(positions[None], (3, *positions.shape)))
        q = apply_mrope(q, mp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mp, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def _mask_bias(qpos: jax.Array, kpos: jax.Array, attn_type: str,
               window: Optional[int]) -> jax.Array:
    """(..., Q, K) additive bias in fp32. qpos: (...,Q), kpos: (...,K)."""
    if attn_type == "bidirectional":
        allowed = jnp.ones(qpos.shape[:-1] + (qpos.shape[-1],
                                              kpos.shape[-1]), bool)
    else:
        allowed = qpos[..., :, None] >= kpos[..., None, :]
    if window is not None:
        allowed &= (qpos[..., :, None] - kpos[..., None, :]) < window
    return jnp.where(allowed, 0.0, NEG_INF).astype(jnp.float32)


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,KV,D) -> (B,S,H,D) by repeating each KV head over its group.

    A static-index gather: under tensor parallelism each model shard slices
    the KV heads it needs locally — this keeps GSPMD from the degenerate
    reshard that a fused (kv, group) einsum formulation provokes."""
    b, s, kv, d = k.shape
    if kv == n_heads:
        return k
    idx = jnp.repeat(jnp.arange(kv), n_heads // kv)
    return jnp.take(k, idx, axis=2)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          bias: jax.Array) -> jax.Array:
    """q: (B,Q,H,D), k/v: (B,K,KV,D), bias: (B,Q,K).
    Returns (B,Q,H,D). fp32 softmax, bf16 matmuls with fp32 accum."""
    b, qlen, h, d = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (d ** -0.5)
    scores = scores + bias[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    # bf16 dot: the MXU accumulates in fp32 internally; forcing f32 HLO
    # output would make every weight cotangent f32 (2x scan-carry memory).
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def full_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig,
    *,
    q_chunk: int = 2048,
    attn_type: Optional[str] = None,
    window: Optional[int] = None,
    impl: str = "xla",
) -> jax.Array:
    """Training/prefill attention. q: (B,S,H,D); k,v: (B,S,KV,D).

    impl="pallas"/"pallas_interpret" routes through the flash-attention
    kernel (kernels/flash_attention.py): heads fold into the grid's batch
    dim, KV heads expand to full heads first (GQA)."""
    b, s, h, d = q.shape
    atype = attn_type or cfg.attn_type
    win = window if window is not None else cfg.sliding_window
    if impl in ("pallas", "pallas_interpret") and s >= 128 and s % 128 == 0:
        from repro.kernels import ops as kops
        kf = _expand_kv(k, h)
        vf = _expand_kv(v, h)
        fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        out = kops.flash_attention(
            fold(q), fold(kf), fold(vf),
            impl="interpret" if impl == "pallas_interpret" else "pallas",
            causal=atype != "bidirectional", window=win)
        return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    kpos = _positions((b,), k.shape[1])
    if s % q_chunk:  # largest divisor of s that fits the requested chunk
        from repro.models.mamba import fit_chunk
        q_chunk = fit_chunk(s, q_chunk)
    if s <= q_chunk:
        qpos = _positions((b,), s)
        bias = _mask_bias(qpos, kpos, atype, win)
        return _sdpa(q, k, v, bias)
    n_chunks = s // q_chunk

    def body(carry, xs):
        qc, start = xs
        qpos = start[:, None] + _positions((b,), q_chunk)
        bias = _mask_bias(qpos, kpos, atype, win)
        return carry, _sdpa(qc, k, v, bias)

    q_chunks = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    starts = (jnp.arange(n_chunks) * q_chunk)[:, None].repeat(b, 1)
    _, out = jax.lax.scan(body, None, (q_chunks, starts))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def decode_span_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    cache_pos: jax.Array, cfg: ModelConfig,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """T-token span attention against an append-only (non-ring) cache.

    q: (B,T,H,D) — T consecutive tokens of one request (a speculative
    draft-verify span, or a suffix prefill behind a cached prefix);
    caches: (B,S,KV,D) at absolute slots (the paged gather view).
    cache_pos: (B,) valid token count BEFORE the span; the span's own
    k/v must already be written, query t (absolute position
    cache_pos + t) attends causally through its own position."""
    b, s, kv, d = k_cache.shape
    t = q.shape[1]
    win = window if window is not None else cfg.sliding_window
    qpos = cache_pos[:, None] + jnp.arange(t)[None, :]  # (B, T)
    spos = jnp.arange(s)[None, None, :]
    valid = spos <= qpos[..., None]  # (B, T, S)
    if win is not None:
        valid &= spos > qpos[..., None] - win
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    return _sdpa(q, k_cache, v_cache, bias)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    cache_pos: jax.Array, cfg: ModelConfig,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token attention against a KV cache.

    q: (B,1,H,D); caches: (B,W,KV,D) where W is the cache window (full
    seq_len, or sliding window size for SWA archs — ring-buffered).
    cache_pos: (B,) int32 — number of valid tokens (the new token's k/v must
    already be written). For ring buffers, slot i holds absolute position
    p = i + W*floor((cache_pos-1-i)/W) — validity is handled via the
    absolute-position map below.
    """
    b, w, kv, d = k_cache.shape
    h = q.shape[2]
    win = window if window is not None else cfg.sliding_window
    slot = jnp.arange(w)
    # absolute position held by each slot under ring addressing
    wraps = jnp.maximum(cache_pos[:, None] - 1 - slot[None, :], 0) // w
    abs_pos = slot[None, :] + wraps * w
    valid = abs_pos < cache_pos[:, None]
    if win is not None:
        valid &= abs_pos >= (cache_pos[:, None] - win)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # (B,W)
    kf = _expand_kv(k_cache, h)
    vf = _expand_kv(v_cache, h)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, kf,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    scores = scores + bias[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(vf.dtype), vf)
    return out.astype(q.dtype)
