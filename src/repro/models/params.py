"""Parameter specification and initialization (framework-native, no flax).

A model is described by a pytree (nested dicts) of ``ParamSpec`` leaves.
From the same spec tree we derive: initialized parameters (deterministic
per-leaf keys folded from the path), the logical-axes tree for sharding,
and ShapeDtypeStructs for dry-run lowering.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def normal_init(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(
            dtype)
    return init


def zeros_init() -> Initializer:
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)
    return init


def ones_init() -> Initializer:
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)
    return init


def constant_init(value: float) -> Initializer:
    def init(key, shape, dtype):
        return jnp.full(shape, value, dtype)
    return init


def uniform_init(lo: float, hi: float) -> Initializer:
    def init(key, shape, dtype):
        return jax.random.uniform(
            key, shape, jnp.float32, lo, hi).astype(dtype)
    return init


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: Initializer = dataclasses.field(default_factory=normal_init)
    dtype: Any = None  # None -> model default

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"shape {self.shape} rank != logical {self.logical}")


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _fold_path(key: jax.Array, path: str) -> jax.Array:
    # stable across processes: hash the path string
    h = np.uint32(2166136261)
    for ch in path.encode():
        h = np.uint32((int(h) ^ ch) * 16777619 & 0xFFFFFFFF)
    return jax.random.fold_in(key, int(h))


def init_params(key: jax.Array, spec_tree: Any, default_dtype=jnp.float32):
    """Materialize parameters; per-leaf keys folded from tree paths."""

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec)[0]

    def materialize(path, spec: ParamSpec):
        path_str = "/".join(str(p) for p in path)
        dtype = spec.dtype or default_dtype
        return spec.init(_fold_path(key, path_str), spec.shape, dtype)

    flat = [materialize(p, s) for p, s in leaves_with_paths]
    treedef = jax.tree_util.tree_structure(spec_tree, is_leaf=is_spec)
    return jax.tree_util.tree_unflatten(treedef, flat)


def axes_tree(spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=is_spec)


def shapes_tree(spec_tree: Any, default_dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        spec_tree, is_leaf=is_spec)


def param_count(spec_tree: Any) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(spec_tree, is_leaf=is_spec))


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(
            x.dtype, jnp.floating) else x, tree)
