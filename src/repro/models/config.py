"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes any of: dense decoder-only transformers (GQA,
optional QKV bias, RoPE/M-RoPE), MoE transformers (top-k routing, optional
sliding-window attention), hybrid Mamba/attention stacks (Jamba-style block
patterns), attention-free RWKV6, and encoder-decoder audio models (Whisper)
whose modality frontend is a stub (precomputed frame/patch embeddings).

Layer stacks are described as repeated *blocks* of ``block_len`` sublayers
(scan runs over blocks). ``sublayer_kinds()`` expands the per-block pattern:
most archs are 1 block-layer of kind "attn"; Jamba uses block_len=8 with
attention at one position and Mamba elsewhere, MoE on every 2nd sublayer.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention
    attn_type: str = "causal"  # causal | bidirectional
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # tokens; None = full attention
    rope_theta: float = 1e4
    pos_emb: str = "rope"  # rope | mrope | learned | none
    mrope_sections: Tuple[int, ...] = ()  # head_dim/2 split for M-RoPE

    # mlp
    mlp_act: str = "swiglu"  # swiglu | gelu

    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE on sublayers where (idx % moe_every)==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # ssm (mamba) / rwkv
    ssm_state_dim: int = 16
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model/16)
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 128

    # block structure (scan unit)
    block_len: int = 1
    attn_positions: Tuple[int, ...] = (0,)  # which sublayers are attention
    default_kind: str = "attn"  # kind of non-attention sublayers

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frames fed to the encoder stub
    cross_attention: bool = False

    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ------------------------------------------------------------------

    def __post_init__(self) -> None:
        if self.n_layers % self.block_len:
            raise ValueError("n_layers must be a multiple of block_len")
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must divide by n_kv_heads")

    @property
    def n_blocks(self) -> int:
        return self.n_layers // self.block_len

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode against a 500k context with bounded state?

        True for attention-free (rwkv), hybrid (jamba: only 1-in-8 layers
        keep KV), and sliding-window attention (bounded KV)."""
        kinds = set(self.sublayer_kinds())
        if "attn" not in kinds:
            return True
        if kinds - {"attn"}:
            return True  # hybrid
        return self.sliding_window is not None

    def sublayer_kinds(self) -> Tuple[str, ...]:
        """Kinds of the ``block_len`` sublayers inside one scan block."""
        return tuple(
            "attn" if i in self.attn_positions else self.default_kind
            for i in range(self.block_len)
        )

    def sublayer_has_moe(self, idx: int) -> bool:
        if not self.n_experts:
            return False
        return idx % self.moe_every == self.moe_offset

    def moe_mask(self) -> Tuple[bool, ...]:
        return tuple(self.sublayer_has_moe(i) for i in range(self.block_len))

    # ---- parameter counting (used for 6*N*D and config validation) -------

    def attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def dense_mlp_params(self) -> int:
        mult = 3 if self.mlp_act == "swiglu" else 2
        return mult * self.d_model * self.d_ff

    def expert_mlp_params(self) -> int:
        mult = 3 if self.mlp_act == "swiglu" else 2
        return mult * self.d_model * self.d_ff

    def mamba_params(self) -> int:
        di, n, dr = self.d_inner, self.ssm_state_dim, self.dt_rank
        return (self.d_model * 2 * di  # in_proj (x and gate)
                + di * self.ssm_conv_width  # depthwise conv
                + di * (dr + 2 * n)  # x -> (dt, B, C)
                + dr * di  # dt_proj
                + di * n  # A_log
                + di  # D
                + di * self.d_model)  # out_proj

    def rwkv_params(self) -> int:
        d = self.d_model
        # r,k,v,g,o projections + data-dependent decay lora + time-mix params
        lora = d * 64 * 2 + d * 32 * 2
        return 5 * d * d + lora + 4 * d

    def params_per_sublayer(self, idx: int) -> int:
        kind = self.sublayer_kinds()[idx]
        if kind == "attn":
            core = self.attn_params()
        elif kind == "mamba":
            core = self.mamba_params()
        elif kind == "rwkv":
            core = self.rwkv_params()
        else:
            raise ValueError(kind)
        if kind == "rwkv":
            # rwkv channel-mix replaces the MLP (2 mats)
            mlp = 2 * self.d_model * self.d_ff
        elif self.sublayer_has_moe(idx):
            mlp = self.n_experts * self.expert_mlp_params() + (
                self.d_model * self.n_experts)  # router
        else:
            mlp = self.dense_mlp_params()
        norms = 2 * self.d_model
        return core + mlp + norms

    def total_params(self) -> int:
        per_block = sum(self.params_per_sublayer(i)
                        for i in range(self.block_len))
        total = per_block * self.n_blocks
        total += self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model  # lm head
        total += self.d_model  # final norm
        if self.is_encoder_decoder:
            enc_layer = (self.attn_params() + self.dense_mlp_params()
                         + 2 * self.d_model)
            total += self.encoder_layers * enc_layer
            # decoder cross-attention blocks
            total += self.n_layers * (self.attn_params() + self.d_model)
            total += self.encoder_seq * self.d_model  # learned enc pos emb
        if self.pos_emb == "learned":
            total += 32768 * self.d_model  # learned decoder pos table
            # (models/encdec.MAX_DEC_POSITIONS, sized for decode_32k)
        return int(total)

    def active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            total = self.total_params()
        else:
            per_block = 0
            for i in range(self.block_len):
                p = self.params_per_sublayer(i)
                if self.sublayer_has_moe(i):
                    p -= (self.n_experts - self.experts_per_token) * \
                        self.expert_mlp_params()
                per_block += p
            total = per_block * self.n_blocks
            total += self.vocab_size * self.d_model * (
                1 if self.tie_embeddings else 2)
            total += self.d_model
        return int(total)
