"""Mixture-of-Experts FFN: capacity dispatch (training) + sort-based
dropless dispatch (serving).

The dispatch pattern is the paper's SparseCore story at the framework level:
fine-grained sort/scatter of per-token vectors (vs the dense AllReduce of
parameter tensors). Two dispatch modes share one router:

* ``dispatch="capacity"`` — GShard-style training dispatch: top-k routing
  -> position-in-expert via one-hot cumsum (top-1 assignments take priority
  over top-2, etc.) -> scatter into an (E, capacity, D) buffer (overflow
  tokens drop, ``mode="drop"``) -> batched expert matmuls -> gather back
  and combine with renormalized gate weights. ``dropless=True`` sizes the
  buffer so nothing can drop — correct, but it burns an (E, T, D) buffer.

* ``dispatch="grouped"`` — sort-based dropless serving dispatch: stable-
  argsort the (T*k) assignments by expert, pad each expert's group to a
  ``block_m`` boundary, run the m-grouped contiguous GEMM Pallas kernel
  (kernels/moe_gemm.py) over the sorted rows with a scalar-prefetched
  tile->expert table, then unpermute and combine with the renormalized
  gate weights. No capacity buffer, no drops: the working set is
  M_pad = round_up(T*k + E*(block_m-1), block_m) rows instead of E*T.
  int8 expert weights (``quantize_moe_params``) dequantize inside the
  kernel via per-expert scales; experts shard over the "data" mesh axis
  through the shard_map wrapper in kernels/ops.py (expert parallelism).

Aux losses (returned, weighted by the trainer): Switch-style load-balance
loss and router z-loss — identical across dispatch modes.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.ops import swiglu, gelu
from repro.models.params import ParamSpec, normal_init

Array = jax.Array


def moe_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": ParamSpec((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        specs["w_gate"] = ParamSpec((e, d, f),
                                    ("expert", "embed", "expert_mlp"))
    return specs


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


# Bound the dispatch working set for either mode: capacity dispatch
# materializes (E, C, D); grouped dispatch materializes the sorted
# M_pad = round_up(T*k + E*(block_m-1), block_m) row buffer. Chunks above
# this token count scan in sequence-chunks, so M_pad (like C) is per-chunk
# and the grouped buffer never exceeds ~chunk_tokens * k rows.
MOE_CHUNK_TOKENS = 65536

# Default m-tile for the grouped GEMM. CI exercises interpret mode at
# smoke scale, where a small tile keeps padding (≤ E*(block_m-1) wasted
# rows) negligible; on TPU hardware raise this to the MXU-aligned 128.
GROUPED_BLOCK_M = 8

_EXPERT_WEIGHTS = ("w_up", "w_gate", "w_down")


def _noshard(x, logical):
    return x


def quantize_moe_params(params: Dict[str, Array]) -> Dict[str, Array]:
    """Symmetric per-expert int8 quantization of the expert weights.

    Each of w_up/w_gate/w_down becomes int8 with a fp32 per-expert scalar
    scale under ``<name>_scale`` (E,) — extending the serving stack's
    quantization-native story from KV pages to weights. The router stays
    full precision (its logits feed top-k; quantization there changes
    routing, not just values). Both dispatch modes consume the quantized
    dict: grouped dequantizes inside the kernel, capacity dequantizes
    eagerly per einsum."""
    out = dict(params)
    for name in _EXPERT_WEIGHTS:
        if name not in params:
            continue
        w = params[name].astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2)) / 127.0,
                            1e-12)
        q = jnp.clip(jnp.round(w / scale[:, None, None]), -127, 127)
        out[name] = q.astype(jnp.int8)
        out[name + "_scale"] = scale
    return out


def _weight(params: Dict[str, Array], name: str, compute_dtype) -> Array:
    """Expert weight in compute dtype, dequantizing int8 if scaled."""
    w = params[name]
    scale = params.get(name + "_scale")
    if scale is None:
        return w.astype(compute_dtype)
    return (w.astype(jnp.float32)
            * scale[:, None, None]).astype(compute_dtype)


def _route(params: Dict[str, Array], xt: Array, compute_dtype, k: int):
    """Shared router: fp32 logits/probs, renormalized top-k gates."""
    logits = (xt @ params["router"].astype(compute_dtype)).astype(
        jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_w, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    return logits, probs, gate_w, gate_idx


def _aux_losses(logits: Array, probs: Array, gate_idx: Array,
                t: int, k: int, e: int) -> Dict[str, Array]:
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (t * k))  # fraction of assignments per expert
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return {"load_balance": load_balance, "router_z": z_loss}


# ---------------------------------------------------------------------------
# Sort-based dropless dispatch (grouped)
# ---------------------------------------------------------------------------


class GroupedDispatch(NamedTuple):
    """Static-shape plan for the sort-based dropless dispatch.

    ``row_src`` (M_pad,): source token row per sorted slot (-1 = pad row).
    ``dest`` (T*k,): sorted slot of each token-major assignment, i.e. the
    inverse permutation the combine gathers through.
    ``block_experts`` (M_pad // block_m,): expert id per m-tile (-1 =
    pad-only tile) — the scalar-prefetched kernel metadata.
    ``counts`` (E,): assignments per expert; ``offsets`` (E+1,): their
    cumsum (monotone, offsets[-1] == T*k); ``padded_starts`` (E,): each
    expert's block-aligned group start in the sorted buffer.
    """
    row_src: Array
    dest: Array
    block_experts: Array
    counts: Array
    offsets: Array
    padded_starts: Array

    @property
    def padded_rows(self) -> int:
        return self.row_src.shape[0]


def grouped_dispatch_plan(gate_idx: Array, *, n_experts: int,
                          block_m: int = GROUPED_BLOCK_M
                          ) -> GroupedDispatch:
    """Build the sorted, block-aligned dispatch plan from (T, k) routing.

    All shapes are static: the sorted buffer is sized at the worst-case
    round_up(T*k + E*(block_m-1), block_m) — every expert's group padded
    to a block_m boundary — so each m-tile maps to exactly one expert."""
    t, k = gate_idx.shape
    tk = t * k
    e, bm = n_experts, block_m
    m_pad = -(-(tk + e * (bm - 1)) // bm) * bm
    nb = m_pad // bm

    flat_e = gate_idx.reshape(-1).astype(jnp.int32)  # token-major (T*k,)
    order = jnp.argsort(flat_e)  # stable: ties keep token order
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)])
    padded = -(-counts // bm) * bm
    pad_ends = jnp.cumsum(padded)
    padded_starts = pad_ends - padded

    # Rank of each sorted assignment within its expert group, then its
    # destination slot in the block-aligned buffer.
    rank = jnp.arange(tk, dtype=jnp.int32) - offsets[sorted_e]
    dest_sorted = padded_starts[sorted_e] + rank
    dest = jnp.zeros((tk,), jnp.int32).at[order].set(dest_sorted)
    row_src = jnp.full((m_pad,), -1, jnp.int32).at[dest_sorted].set(
        order // k)

    tile_starts = jnp.arange(nb, dtype=jnp.int32) * bm
    block_experts = jnp.searchsorted(pad_ends, tile_starts,
                                     side="right").astype(jnp.int32)
    block_experts = jnp.where(tile_starts < pad_ends[-1],
                              block_experts, -1)
    return GroupedDispatch(row_src, dest, block_experts, counts, offsets,
                           padded_starts)


def grouped_permute(xt: Array, plan: GroupedDispatch, dtype) -> Array:
    """Gather token rows (T, D) into sorted order (M_pad, D); pad rows
    are zero (never read by the combine; psum identity under EP)."""
    src = jnp.maximum(plan.row_src, 0)
    xs = xt[src].astype(dtype)
    return jnp.where(plan.row_src[:, None] >= 0, xs,
                     jnp.zeros((), dtype))


def grouped_combine(y: Array, plan: GroupedDispatch, gate_w: Array,
                    t: int, k: int) -> Array:
    """Unpermute (M_pad, D) expert outputs back to token order and
    combine the k assignments with renormalized gate weights -> (T, D)."""
    gathered = y[plan.dest]  # (T*k, D) token-major
    weights = gate_w.reshape(-1).astype(y.dtype)  # (T*k,)
    d = y.shape[-1]
    return (gathered * weights[:, None]).reshape(t, k, d).sum(axis=1)


def _moe_ffn_grouped(params: Dict[str, Array], x: Array, cfg: ModelConfig,
                     compute_dtype, shard, impl: str, block_m: int, mesh,
                     expert_axis: str) -> Tuple[Array, Dict[str, Array]]:
    b, s, d = x.shape
    t = b * s
    k, e = cfg.experts_per_token, cfg.n_experts
    xt = x.reshape(t, d)

    logits, probs, gate_w, gate_idx = _route(params, xt, compute_dtype, k)
    aux = _aux_losses(logits, probs, gate_idx, t, k, e)

    names = [n for n in _EXPERT_WEIGHTS if n in params]
    has_scale = any(n + "_scale" in params for n in names)
    ws, scales = [], []
    for n in names:
        sc = params.get(n + "_scale")
        ws.append(params[n] if sc is not None
                  else params[n].astype(compute_dtype))
        scales.append(sc)

    # Expert parallelism: shard experts over ``expert_axis`` when they
    # divide it, else fall back to fully-replicated compute on the mesh.
    ep = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if expert_axis in sizes and e % sizes[expert_axis] == 0:
            ep = sizes[expert_axis]
    e_local = e // ep

    # The whole sorted-dispatch pipeline — plan, permute, grouped GEMM,
    # unpermute/combine — runs inside ONE shard_map on serving meshes.
    # The plan's integer sort/scatter/searchsorted math must compile
    # per-device: left to GSPMD, sharding propagation through the decode
    # scan partitions those scatters and the computed plan (hence the
    # routed outputs) silently diverges from the single-host program.
    # shard_map replicates the (small) token activations, shards only the
    # expert dim of the weights, and psums the expert-partial rows — pad
    # rows and non-local tiles are zero, the psum identity.
    def run(xt_, gate_w_, gate_idx_, *wx):
        ws_ = wx[:len(names)]
        scs_ = wx[len(names):] if has_scale else (None,) * len(names)
        plan = grouped_dispatch_plan(gate_idx_, n_experts=e,
                                     block_m=block_m)
        xs = grouped_permute(xt_, plan, compute_dtype)
        gids = plan.block_experts
        if ep > 1:
            lo = jax.lax.axis_index(expert_axis) * e_local
            g = gids - lo
            gids = jnp.where((g >= 0) & (g < e_local), g, -1)
        by = dict(zip(names, zip(ws_, scs_)))

        def gm(rows, name):
            w, sc = by[name]
            return kops.grouped_matmul(rows, w, gids, w_scale=sc,
                                       impl=impl)

        up = gm(xs, "w_up")
        h = swiglu(gm(xs, "w_gate"), up) if cfg.mlp_act == "swiglu" \
            else gelu(up)
        down = gm(h.astype(compute_dtype), "w_down")
        if ep > 1:
            down = jax.lax.psum(down, expert_axis)
        return grouped_combine(down, plan, gate_w_, t, k)

    args = [xt, gate_w, gate_idx] + ws + (scales if has_scale else [])
    if mesh is None:
        out = run(*args)
    else:
        P = jax.sharding.PartitionSpec
        wspec = P(expert_axis) if ep > 1 else P()
        in_specs = [P(), P(), P()] + [wspec] * len(names) * (
            2 if has_scale else 1)
        out = shard_map(run, mesh=mesh, in_specs=tuple(in_specs),
                        out_specs=P(), check_rep=False)(*args)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Capacity dispatch (GShard) + the public entry point
# ---------------------------------------------------------------------------


def moe_ffn(params: Dict[str, Array], x: Array, cfg: ModelConfig,
            compute_dtype,
            chunk_tokens: int = MOE_CHUNK_TOKENS,
            shard=_noshard,
            dropless: bool = False,
            dispatch: str = "capacity",
            impl: str = "ref",
            block_m: int = GROUPED_BLOCK_M,
            mesh=None,
            expert_axis: str = "data"
            ) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, S, D) -> (out, aux_losses).

    Token count above ``chunk_tokens`` is processed in sequence-chunks
    (scan), bounding the dispatch working set — (E, C, D) for capacity,
    the sorted M_pad row buffer for grouped. Capacity is then per-chunk,
    which is the standard serving/prefill trade-off; grouped results are
    chunk-invariant (each row's GEMM is independent of group packing).

    ``dispatch="capacity"`` is the GShard training path. ``dropless=True``
    sizes its buffer so no assignment can overflow (capacity = chunk
    token count): each token's output becomes independent of the rest of
    the batch. Serving paths require this — with capacity drops, prefill
    results depend on how many other tokens share the dispatch, so an
    incremental decode can never bit-match a longer prefill. Training
    keeps the capacity-dropping dispatch (the load-balance pressure the
    aux losses assume).

    ``dispatch="grouped"`` is the sort-based dropless serving path (see
    module docstring): dropless by construction, routed through the
    m-grouped GEMM kernel. ``impl`` selects the kernel body ("pallas" /
    "interpret" / "ref"); ``mesh`` + ``expert_axis`` enable the
    expert-parallel shard_map wrapper."""
    b, s, d = x.shape
    if dispatch == "grouped":
        flat = partial(_moe_ffn_grouped, compute_dtype=compute_dtype,
                       shard=shard, impl=impl, block_m=block_m, mesh=mesh,
                       expert_axis=expert_axis)
    elif dispatch == "capacity":
        flat = partial(_moe_ffn_flat, compute_dtype=compute_dtype,
                       shard=shard, dropless=dropless)
    else:
        raise ValueError(f"unknown MoE dispatch {dispatch!r}")
    if b * s > chunk_tokens and (b * s) % chunk_tokens == 0 and \
            s % (b * s // chunk_tokens) == 0:
        n_chunks = b * s // chunk_tokens
        sc = s // n_chunks
        xc = x.reshape(b, n_chunks, sc, d).transpose(1, 0, 2, 3)

        def body(_, xi):
            out, aux = flat(params, xi, cfg)
            return None, (out, aux)

        _, (outs, auxs) = jax.lax.scan(body, None, xc)
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = jax.tree.map(lambda a: a.mean(0), auxs)
        return out, aux
    return flat(params, x, cfg)


def _moe_ffn_flat(params: Dict[str, Array], x: Array, cfg: ModelConfig,
                  compute_dtype, shard=_noshard, dropless: bool = False
                  ) -> Tuple[Array, Dict[str, Array]]:
    b, s, d = x.shape
    t = b * s
    k, e = cfg.experts_per_token, cfg.n_experts
    xt = x.reshape(t, d)

    logits, probs, gate_w, gate_idx = _route(params, xt, compute_dtype, k)

    # An expert receives at most one assignment per token (top-k indices are
    # distinct), so capacity = t can never drop.
    cap = t if dropless else capacity(t, cfg)
    # Priority order: all top-1 assignments, then top-2, ... (GShard).
    flat_idx = gate_idx.T.reshape(-1)  # (k*T,)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (kT, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap

    xk = jnp.broadcast_to(xt[None], (k, t, d)).reshape(k * t, d)
    xk = jnp.where(keep[:, None], xk, jnp.zeros((), compute_dtype))
    xk = shard(xk, ("batch", "embed"))
    dispatched = jnp.zeros((e, cap, d), compute_dtype).at[
        flat_idx, pos_in_e].add(xk, mode="drop")
    dispatched = shard(dispatched, ("expert", "exp_cap", None))

    # Expert matmuls: E sharded over data (EP), hidden over model (TP).
    up = jnp.einsum("ecd,edf->ecf", dispatched,
                    _weight(params, "w_up", compute_dtype))
    if cfg.mlp_act == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", dispatched,
                          _weight(params, "w_gate", compute_dtype))
        h = swiglu(gate, up)
    else:
        h = gelu(up)
    h = shard(h, ("expert", "exp_cap", "expert_mlp"))
    down = jnp.einsum("ecf,efd->ecd", h,
                      _weight(params, "w_down", compute_dtype))
    down = shard(down, ("expert", "exp_cap", None))

    gathered = down.at[flat_idx, pos_in_e].get(
        mode="fill", fill_value=0)  # (kT, D)
    gathered = shard(gathered, ("batch", "embed"))
    gathered = jnp.where(keep[:, None], gathered,
                         jnp.zeros((), compute_dtype))
    weights = (gate_w.T.reshape(-1) * keep).astype(compute_dtype)  # (kT,)
    out = (gathered * weights[:, None]).reshape(k, t, d).sum(axis=0)

    aux = _aux_losses(logits, probs, gate_idx, t, k, e)
    return out.reshape(b, s, d), aux


def dense_ffn(params: Dict[str, Array], x: Array, cfg: ModelConfig,
              compute_dtype) -> Array:
    up = x @ params["w_up"].astype(compute_dtype)
    if cfg.mlp_act == "swiglu":
        h = swiglu(x @ params["w_gate"].astype(compute_dtype), up)
    else:
        h = gelu(up)
    return h @ params["w_down"].astype(compute_dtype)


def dense_ffn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    specs = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs
