"""Mixture-of-Experts FFN: GShard-style capacity dispatch, expert-parallel.

The dispatch pattern is the paper's SparseCore story at the framework level:
fine-grained scatter/gather of per-token vectors across the pod (vs the
dense AllReduce of parameter tensors). Experts are sharded over the "data"
mesh axis (expert parallelism); expert hidden dims over "model" (tensor
parallelism). GSPMD materializes the token movement as all-to-all-like
collectives — visible in the dry-run HLO and costed by the roofline.

Dispatch: top-k routing -> position-in-expert via one-hot cumsum (top-1
assignments take priority over top-2, etc.) -> scatter into an
(E, capacity, D) buffer (overflow tokens drop, mode="drop") -> batched
expert matmuls -> gather back and combine with renormalized gate weights.

Aux losses (returned, weighted by the trainer): Switch-style load-balance
loss and router z-loss.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.ops import swiglu, gelu
from repro.models.params import ParamSpec, normal_init

Array = jax.Array


def moe_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": ParamSpec((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        specs["w_gate"] = ParamSpec((e, d, f),
                                    ("expert", "embed", "expert_mlp"))
    return specs


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


MOE_CHUNK_TOKENS = 65536  # bound the (E, C, D) dispatch buffer


def _noshard(x, logical):
    return x


def moe_ffn(params: Dict[str, Array], x: Array, cfg: ModelConfig,
            compute_dtype,
            chunk_tokens: int = MOE_CHUNK_TOKENS,
            shard=_noshard,
            dropless: bool = False) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, S, D) -> (out, aux_losses).

    Token count above ``chunk_tokens`` is processed in sequence-chunks
    (scan), bounding dispatch-buffer memory; capacity is then per-chunk,
    which is the standard serving/prefill trade-off.

    ``dropless=True`` sizes the dispatch buffer so no assignment can
    overflow (capacity = chunk token count): each token's output becomes
    independent of the rest of the batch. Serving paths require this —
    with capacity drops, prefill results depend on how many other tokens
    share the dispatch, so an incremental decode can never bit-match a
    longer prefill. Training keeps the capacity-dropping GShard dispatch
    (the load-balance pressure the aux losses assume)."""
    b, s, d = x.shape
    if b * s > chunk_tokens and (b * s) % chunk_tokens == 0 and \
            s % (b * s // chunk_tokens) == 0:
        n_chunks = b * s // chunk_tokens
        sc = s // n_chunks
        xc = x.reshape(b, n_chunks, sc, d).transpose(1, 0, 2, 3)

        def body(_, xi):
            out, aux = _moe_ffn_flat(params, xi, cfg, compute_dtype, shard,
                                     dropless)
            return None, (out, aux)

        _, (outs, auxs) = jax.lax.scan(body, None, xc)
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = jax.tree.map(lambda a: a.mean(0), auxs)
        return out, aux
    return _moe_ffn_flat(params, x, cfg, compute_dtype, shard, dropless)


def _moe_ffn_flat(params: Dict[str, Array], x: Array, cfg: ModelConfig,
                  compute_dtype, shard=_noshard, dropless: bool = False
                  ) -> Tuple[Array, Dict[str, Array]]:
    b, s, d = x.shape
    t = b * s
    k, e = cfg.experts_per_token, cfg.n_experts
    xt = x.reshape(t, d)

    logits = (xt @ params["router"].astype(compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_w, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # An expert receives at most one assignment per token (top-k indices are
    # distinct), so capacity = t can never drop.
    cap = t if dropless else capacity(t, cfg)
    # Priority order: all top-1 assignments, then top-2, ... (GShard).
    flat_idx = gate_idx.T.reshape(-1)  # (k*T,)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (kT, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_in_e = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap

    xk = jnp.broadcast_to(xt[None], (k, t, d)).reshape(k * t, d)
    xk = jnp.where(keep[:, None], xk, jnp.zeros((), compute_dtype))
    xk = shard(xk, ("batch", "embed"))
    dispatched = jnp.zeros((e, cap, d), compute_dtype).at[
        flat_idx, pos_in_e].add(xk, mode="drop")
    dispatched = shard(dispatched, ("expert", "exp_cap", None))

    # Expert matmuls: E sharded over data (EP), hidden over model (TP).
    up = jnp.einsum("ecd,edf->ecf", dispatched,
                    params["w_up"].astype(compute_dtype))
    if cfg.mlp_act == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", dispatched,
                          params["w_gate"].astype(compute_dtype))
        h = swiglu(gate, up)
    else:
        h = gelu(up)
    h = shard(h, ("expert", "exp_cap", "expert_mlp"))
    down = jnp.einsum("ecf,efd->ecd", h,
                      params["w_down"].astype(compute_dtype))
    down = shard(down, ("expert", "exp_cap", None))

    gathered = down.at[flat_idx, pos_in_e].get(
        mode="fill", fill_value=0)  # (kT, D)
    gathered = shard(gathered, ("batch", "embed"))
    gathered = jnp.where(keep[:, None], gathered,
                         jnp.zeros((), compute_dtype))
    weights = (gate_w.T.reshape(-1) * keep).astype(compute_dtype)  # (kT,)
    out = (gathered * weights[:, None]).reshape(k, t, d).sum(axis=0)

    # Aux losses (fp32).
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (t * k))  # fraction of assignments per expert
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance": load_balance, "router_z": z_loss}
    return out.reshape(b, s, d), aux


def dense_ffn(params: Dict[str, Array], x: Array, cfg: ModelConfig,
              compute_dtype) -> Array:
    up = x @ params["w_up"].astype(compute_dtype)
    if cfg.mlp_act == "swiglu":
        h = swiglu(x @ params["w_gate"].astype(compute_dtype), up)
    else:
        h = gelu(up)
    return h @ params["w_down"].astype(compute_dtype)


def dense_ffn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    specs = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs
