"""Decoder-only language model: specs, train loss, prefill, decode.

Layers run under ``lax.scan`` over stacked block parameters with
``jax.checkpoint`` (remat) around the body — the paper-era recipe for
training big models on HBM-limited accelerators.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocks import (ModelContext, block_cache_spec,
                                 block_decode, block_decode_paged,
                                 block_decode_span, block_decode_span_paged,
                                 block_forward, block_prefill, block_specs,
                                 paged_block_cache_spec, stack_specs)
from repro.models.config import ModelConfig
from repro.models.ops import embed_lookup, rms_norm, softmax_cross_entropy
from repro.models.params import ParamSpec, ones_init

Array = jax.Array

AUX_WEIGHTS = {"load_balance": 0.01, "router_z": 0.001}


def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed")),
        "blocks": stack_specs(block_specs(cfg), cfg.n_blocks),
        "final_norm": ParamSpec((d,), ("embed",), init=ones_init()),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    return specs


def _logits(params: Dict[str, Any], x: Array, cfg: ModelConfig,
            ctx: ModelContext) -> Array:
    if cfg.tie_embeddings:
        head = params["embed"].astype(ctx.compute_dtype).T
    else:
        head = params["lm_head"].astype(ctx.compute_dtype)
    logits = x @ head
    return ctx.shard(logits, ("batch", "seq", "vocab"))


def lm_loss(params: Dict[str, Any], batch: Dict[str, Array],
            cfg: ModelConfig, ctx: ModelContext
            ) -> Tuple[Array, Dict[str, Array]]:
    tokens, labels = batch["tokens"], batch["labels"]
    mrope = batch.get("positions")
    x = embed_lookup(params["embed"], tokens, ctx.compute_dtype)
    x = ctx.shard(x, ("batch", "act_seq", "embed"))

    def body(x, bp):
        x, aux = block_forward(bp, x, cfg, ctx, mrope)
        out_aux = {k: jnp.asarray(aux.get(k, 0.0), jnp.float32)
                   for k in AUX_WEIGHTS}
        return x, out_aux

    x, auxs = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg, ctx)
    mask = batch.get("loss_mask")
    loss, count = softmax_cross_entropy(logits, labels, mask)
    metrics = {"xent": loss, "tokens": count}
    total = loss
    for key, w in AUX_WEIGHTS.items():
        if key in auxs:
            val = auxs[key].mean()
            metrics[key] = val
            total = total + w * val
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def lm_cache_spec(cfg: ModelConfig, batch: int, window: int,
                  ctx: ModelContext) -> Dict[str, Any]:
    blocks = block_cache_spec(cfg, batch, window, ctx)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_blocks, *s.shape), s.dtype),
        blocks)
    return {"blocks": stacked,
            "pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}


def lm_prefill(params: Dict[str, Any], tokens: Array, cfg: ModelConfig,
               ctx: ModelContext, window: int,
               logits_at: Optional[Array] = None,
               pad_left: Optional[Array] = None,
               mrope_positions: Optional[Array] = None
               ) -> Tuple[Array, Dict[str, Any]]:
    """Full-sequence prefill. Returns (last-token logits, cache).

    ``logits_at`` (B,) selects the position whose logits are returned
    (default: the last). Servers that pad prompts to a fixed compile
    length pass the true last-token index per request here; under causal
    attention the padded tail never influences the valid prefix.

    ``pad_left`` (B,) declares the first N positions to be padding for
    *state-family* stacks (mamba/rwkv): their embeddings are zeroed and
    the recurrent state provably stays at its zero initial value through
    the pad prefix, so servers can pad prompts up to a bucketed compile
    length from the front. Attention sublayers reject it (front padding
    would shift their positions).

    ``mrope_positions`` (3,B,S): explicit multimodal rope rows, the same
    contract the training loss uses (None = text default)."""
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens, ctx.compute_dtype)
    live = None
    if pad_left is not None:
        live = jnp.arange(s)[None, :] >= pad_left[:, None]  # (B, S)
        x = x * live[..., None].astype(x.dtype)
    x = ctx.shard(x, ("batch", "act_seq", "embed"))
    cache0 = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        block_cache_spec(cfg, b, window, ctx))

    def body(x, bp):
        x, new_cache = block_prefill(bp, x, cache0, cfg, ctx,
                                     mrope_positions, seq_mask=live)
        return x, new_cache

    x, caches = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    if logits_at is None:
        xl = x[:, -1:]
        pos = jnp.full((b,), s, jnp.int32)
    else:
        idx = jnp.broadcast_to(logits_at[:, None, None], (b, 1, x.shape[-1]))
        xl = jnp.take_along_axis(x, idx, axis=1)
        pos = logits_at.astype(jnp.int32) + 1
    xl = rms_norm(xl, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, xl, cfg, ctx)
    return logits, {"blocks": caches, "pos": pos}


def lm_decode_step(params: Dict[str, Any], token: Array,
                   cache: Dict[str, Any], cfg: ModelConfig,
                   ctx: ModelContext) -> Tuple[Array, Dict[str, Any]]:
    """token: (B, 1) int32. Returns (logits (B,1,V), new cache)."""
    pos = cache["pos"]
    x = embed_lookup(params["embed"], token, ctx.compute_dtype)
    x = ctx.shard(x, ("batch", None, "embed"))

    def body(x, xs):
        bp, bc = xs
        x, nc = block_decode(bp, x, bc, pos, cfg, ctx)
        return x, nc

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                           cache["blocks"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg, ctx)
    return logits, {"blocks": new_blocks, "pos": pos + 1}


def _span_logits_slice(x: Array, logits_at: Optional[Array]) -> Array:
    """Prefill chunks only need ONE position's logits: gather it before
    the lm head so the vocab projection is (B,1,V), not (B,T,V) —
    spec verify passes ``logits_at=None`` and keeps the whole span."""
    if logits_at is None:
        return x
    b = x.shape[0]
    idx = jnp.broadcast_to(logits_at[:, None, None], (b, 1, x.shape[-1]))
    return jnp.take_along_axis(x, idx, axis=1)


def lm_decode_span(params: Dict[str, Any], tokens: Array,
                   cache: Dict[str, Any], cfg: ModelConfig,
                   ctx: ModelContext,
                   logits_at: Optional[Array] = None,
                   mrope_positions: Optional[Array] = None
                   ) -> Tuple[Array, Dict[str, Any]]:
    """T-token span decode against dense per-slot caches (all sublayer
    families) — the chunked-prefill datapath for hybrid (jamba) stacks.

    tokens: (B,T) int32 at absolute positions ``pos .. pos+T-1`` where
    ``pos = cache["pos"]`` may be negative: positions < 0 are dead
    (the front padding of a right-aligned prompt's first chunk) — their
    embeddings are zeroed, their cache writes dropped, and the residual
    stream stays exactly 0 there, so the recurrent state of mamba/rwkv
    sublayers passes through untouched. Attention caches must hold
    absolute slots (window >= total length; no ring wrap).
    ``logits_at`` (B,): return only that position's logits (B,1,V).
    ``mrope_positions`` (3,B,T): explicit multimodal rope rows for the
    span (None = text default, broadcast from absolute positions).
    Returns (logits, new cache with ``pos`` UNCHANGED — the caller owns
    position bookkeeping, exactly like the paged span path)."""
    pos = cache["pos"]
    b, t = tokens.shape
    posn = pos[:, None] + jnp.arange(t)[None, :]
    live = posn >= 0  # (B, T)
    x = embed_lookup(params["embed"], tokens, ctx.compute_dtype)
    x = x * live[..., None].astype(x.dtype)
    x = ctx.shard(x, ("batch", None, "embed"))

    def body(x, xs):
        bp, bc = xs
        x, nc = block_decode_span(bp, x, bc, pos, live, cfg, ctx,
                                  mrope_positions)
        return x, nc

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                           cache["blocks"]))
    x = _span_logits_slice(x, logits_at)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg, ctx)
    return logits, {"blocks": new_blocks, "pos": pos}


# -- paged serving state ----------------------------------------------------


def lm_paged_state_spec(cfg: ModelConfig, num_pages: int, page_size: int,
                        max_batch: int, max_pages_per_seq: int,
                        ctx: ModelContext) -> Dict[str, Any]:
    """ShapeDtypeStructs for the paged decode state (see blocks.py)."""
    per_block = paged_block_cache_spec(cfg, num_pages, page_size, ctx)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_blocks, *s.shape), s.dtype),
        per_block)
    return {
        "pages": stacked,
        "page_table": jax.ShapeDtypeStruct(
            (max_batch, max_pages_per_seq), jnp.int32),
        "pos": jax.ShapeDtypeStruct((max_batch,), jnp.int32),
    }


def lm_decode_step_paged(params: Dict[str, Any], token: Array,
                         state: Dict[str, Any], cfg: ModelConfig,
                         ctx: ModelContext) -> Tuple[Array, Dict[str, Any]]:
    """token: (B, 1) int32 against the paged pool.

    Returns (logits (B,1,V), new state with pos advanced). Callers that
    freeze finished requests overwrite ``pos`` afterwards."""
    pos = state["pos"]
    table = state["page_table"]
    x = embed_lookup(params["embed"], token, ctx.compute_dtype)
    x = ctx.shard(x, ("batch", None, "embed"))

    def body(x, xs):
        bp, layer_pages = xs
        x, np_ = block_decode_paged(bp, x, layer_pages, table, pos, cfg, ctx)
        return x, np_

    x, new_pages = jax.lax.scan(body, x, (params["blocks"], state["pages"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg, ctx)
    return logits, {"pages": new_pages, "page_table": table, "pos": pos + 1}


def lm_decode_span_paged(params: Dict[str, Any], tokens: Array,
                         state: Dict[str, Any], cfg: ModelConfig,
                         ctx: ModelContext,
                         valid: Optional[Array] = None,
                         logits_at: Optional[Array] = None,
                         mrope_positions: Optional[Array] = None
                         ) -> Tuple[Array, Dict[str, Any]]:
    """T-token span decode against the paged pool (speculative verify /
    suffix prefill / chunked cold prefill).

    tokens: (B,T) int32 at absolute positions ``pos .. pos+T-1``;
    ``valid`` (B,): number of real tokens in the span (default all T) —
    padded tail slots write to the trash page and their logits are
    garbage the caller must ignore. ``logits_at`` (B,): return only
    that position's logits, (B,1,V) — what a prefill chunk wants; spec
    verify keeps the full (B,T,V). ``mrope_positions`` (3,B,T): explicit
    multimodal rope rows for the span (None = text default — broadcast
    absolute positions, exactly what text-only mrope prompts want).
    Returns (logits, new state with
    ``pos`` UNCHANGED — acceptance/rollback bookkeeping is the
    caller's: accepted tokens advance the position frontier, rejected
    ones are simply never covered by it)."""
    pos = state["pos"]
    table = state["page_table"]
    b, t = tokens.shape
    if valid is None:
        valid = jnp.full((b,), t, jnp.int32)
    live = jnp.arange(t)[None, :] < valid[:, None]  # (B, T)
    x = embed_lookup(params["embed"], tokens, ctx.compute_dtype)
    x = ctx.shard(x, ("batch", None, "embed"))

    def body(x, xs):
        bp, layer_pages = xs
        x, np_ = block_decode_span_paged(bp, x, layer_pages, table, pos,
                                         live, cfg, ctx, mrope_positions)
        return x, np_

    x, new_pages = jax.lax.scan(body, x, (params["blocks"], state["pages"]))
    x = _span_logits_slice(x, logits_at)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg, ctx)
    return logits, {"pages": new_pages, "page_table": table, "pos": pos}
