"""RWKV-6 "Finch" layer: linear attention with data-dependent decay.

Recurrence per head (k-dim x v-dim state S):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(ww(x_t))) a per-channel, per-token decay (the "data-
dependent decay" that distinguishes Finch from RWKV-5) and u a learned
current-token bonus.

Evaluation is chunk-parallel: the sequence is cut into small chunks; chunk
boundary states are combined with ``associative_scan`` (elementwise decay ×
rank-chunk updates), and intra-chunk interactions use bounded-exponent
matmuls — per-step log-decay is clamped to >= DECAY_CLAMP so
exp(cum[t-1]-cum[s]) stays in fp32 range for s,t within a chunk. The same
math (same clamp) is the ref oracle for the Pallas kernel in kernels/.

Decode carries (token_shift, state) — constant memory per sequence, which
is why this arch runs the long_500k cell.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec, normal_init, ones_init, zeros_init

Array = jax.Array

DECAY_CLAMP = -4.0  # min per-step log decay; exp(16*4)=6e27 < fp32 max
LORA_DECAY = 64
LORA_MIX = 32


def rwkv_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "r_proj": ParamSpec((d, d), ("embed", "heads")),
        "k_proj": ParamSpec((d, d), ("embed", "heads")),
        "v_proj": ParamSpec((d, d), ("embed", "heads")),
        "g_proj": ParamSpec((d, d), ("embed", "heads")),
        "o_proj": ParamSpec((d, d), ("heads", "embed")),
        # data-dependent decay: low-rank adapter on x
        "w_lora_a": ParamSpec((d, LORA_DECAY), ("embed", None)),
        "w_lora_b": ParamSpec((LORA_DECAY, d), (None, "heads")),
        "w_base": ParamSpec((d,), ("heads",), init=normal_init(0.5)),
        # current-token bonus
        "u_bonus": ParamSpec((h, hd), ("heads", None),
                             init=normal_init(0.5)),
        # token-shift mixing coefficients (r,k,v,g,w)
        "mix": ParamSpec((5, d), (None, "heads"),
                         init=normal_init(0.2)),
    }


def rwkv_channel_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """Channel-mix (RWKV's MLP replacement)."""
    d = cfg.d_model
    return {
        "cm_k": ParamSpec((d, cfg.d_ff), ("embed", "mlp")),
        "cm_v": ParamSpec((cfg.d_ff, d), ("mlp", "embed")),
        "cm_mix": ParamSpec((d,), ("heads",), init=normal_init(0.2)),
    }


def _token_shift(x: Array, prev: Optional[Array]) -> Array:
    """x_{t-1} stream; prev: (B,1,D) carry for decode/chunked prefill."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv_time_mix(
    params: Dict[str, Array], x: Array, cfg: ModelConfig, compute_dtype,
    *,
    chunk: int = 16,
    init_state: Optional[Tuple[Array, Array]] = None,
    return_state: bool = False,
):
    """x: (B,S,D) -> (B,S,D). State = (last_token (B,1,D), S (B,H,hd,hd))."""
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    prev_tok = init_state[0] if init_state is not None else None
    xs = _token_shift(x, prev_tok)
    mix = params["mix"].astype(compute_dtype)  # (5, D)

    def mixed(i):
        return x + mix[i] * (xs - x)

    r = (mixed(0) @ params["r_proj"].astype(compute_dtype)).reshape(
        b, s, h, hd)
    k = (mixed(1) @ params["k_proj"].astype(compute_dtype)).reshape(
        b, s, h, hd)
    v = (mixed(2) @ params["v_proj"].astype(compute_dtype)).reshape(
        b, s, h, hd)
    g = mixed(3) @ params["g_proj"].astype(compute_dtype)
    ww = (mixed(4) @ params["w_lora_a"].astype(compute_dtype)
          ) @ params["w_lora_b"].astype(compute_dtype)
    logw = -jnp.exp(
        (ww + params["w_base"].astype(compute_dtype)).astype(jnp.float32))
    logw = jnp.clip(logw, DECAY_CLAMP, 0.0).reshape(b, s, h, hd)

    from repro.models.mamba import fit_chunk
    u = params["u_bonus"].astype(jnp.float32)  # (H, hd)
    out, last_state = _chunked_wkv(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), logw, u,
        init_state[1].astype(jnp.float32) if init_state is not None
        else jnp.zeros((b, h, hd, hd), jnp.float32),
        chunk=fit_chunk(s, chunk))
    out = out.reshape(b, s, d).astype(compute_dtype)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype)
    out = out @ params["o_proj"].astype(compute_dtype)
    if return_state:
        return out, (x[:, -1:], last_state)
    return out


def _chunked_wkv(r: Array, k: Array, v: Array, logw: Array, u: Array,
                 s0: Array, chunk: int) -> Tuple[Array, Array]:
    """Chunk-parallel WKV. r,k,v,logw: (B,S,H,hd) fp32; s0: (B,H,hd,hd).

    Returns (out (B,S,H,hd), final_state)."""
    b, s, h, hd = r.shape
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk}")
    nc = s // chunk
    rc = r.reshape(b, nc, chunk, h, hd)
    kc = k.reshape(b, nc, chunk, h, hd)
    vc = v.reshape(b, nc, chunk, h, hd)
    lw = logw.reshape(b, nc, chunk, h, hd)

    cum = jnp.cumsum(lw, axis=2)  # within-chunk cumulative log decay
    total = cum[:, :, -1]  # (B,nc,H,hd)
    # decays: key s contributes decayed by exp(total - cum[s]) to boundary
    k_out = kc * jnp.exp(total[:, :, None] - cum)  # bounded: <= exp(0)
    # per-chunk state update: S_out = diag(exp(total)) S_in + sum_s k~_s^T v_s
    delta = jnp.einsum("bnchk,bnchv->bnhkv", k_out, vc)
    a_fac = jnp.exp(total)  # (B,nc,H,hd) decay applied on k-dim

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar[..., None] + br

    a_all, s_all = jax.lax.associative_scan(
        combine, (a_fac.transpose(1, 0, 2, 3),
                  delta.transpose(1, 0, 2, 3, 4)), axis=0)
    # state at START of each chunk: shift right, include s0
    s_all = s_all.transpose(1, 0, 2, 3, 4)  # (B,nc,H,hd,hd)
    a_all = a_all.transpose(1, 0, 2, 3)
    s_starts = jnp.concatenate(
        [jnp.broadcast_to(s0[:, None], (b, 1, h, hd, hd)),
         s_all[:, :-1] + s0[:, None] *
         a_all[:, :-1][..., None]], axis=1)
    s_final = s_all[:, -1] + s0 * a_all[:, -1][..., None]

    # inter-chunk: r_t reads state decayed to t-1 (exclusive cumulative)
    cum_excl = cum - lw  # log decay from chunk start to t-1
    r_in = rc * jnp.exp(cum_excl)
    inter = jnp.einsum("bnchk,bnhkv->bnchv", r_in, s_starts)

    # intra-chunk: pairwise s<t with exponent cum_excl[t] - cum[s] <= 0
    scores = jnp.einsum("bnchk,bnshk->bnhcs",
                        rc * jnp.exp(cum_excl), kc * jnp.exp(-cum))
    # the exp factors combine to exp(cum_excl[t] - cum[s]); mask s<t
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    intra = jnp.einsum("bnhcs,bnshv->bnchv", scores, vc)
    # current-token bonus: r_t . (u * k_t) v_t
    bonus = jnp.einsum("bnchk,bnchk->bnch", rc, kc * u[None, None, None])
    intra = intra + bonus[..., None] * vc

    out = (inter + intra).reshape(b, s, h, hd)
    return out, s_final


def rwkv_channel_mix(params: Dict[str, Array], x: Array, cfg: ModelConfig,
                     compute_dtype,
                     prev: Optional[Array] = None,
                     return_state: bool = False):
    xs = _token_shift(x, prev)
    mix = params["cm_mix"].astype(compute_dtype)
    xm = x + mix * (xs - x)
    hidden = jnp.square(jax.nn.relu(
        (xm @ params["cm_k"].astype(compute_dtype)).astype(jnp.float32)))
    out = hidden.astype(compute_dtype) @ params["cm_v"].astype(compute_dtype)
    if return_state:
        return out, x[:, -1:]
    return out
