"""Block assembly: sublayer specs, forward, and KV/state caches.

A *block* is the scan unit: ``block_len`` sublayers, each
(norm -> core -> residual, norm -> mlp/moe -> residual) where core is
attention, Mamba, or RWKV time-mix per ``cfg.sublayer_kinds()``. Parameters
for all blocks are stacked on a leading n_blocks axis and consumed by
``lax.scan`` — keeping the compiled HLO one-block-sized regardless of depth
(61-layer models compile as fast as 2-layer ones; the roofline analyzer
scales costs by the known trip count).

Caches: every sublayer owns a dict cache (attention: ring-buffered k/v;
mamba: conv window + ssm state; rwkv: token-shift + wkv state). Cache trees
are stacked across blocks and scanned jointly with the parameters during
prefill/decode.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (apply_positional, decode_attention,
                                    decode_span_attention, full_attention)
from repro.models.config import ModelConfig
from repro.models.mamba import (mamba_decode_step, mamba_forward,
                                mamba_param_specs)
from repro.models.moe import (dense_ffn, dense_ffn_specs, moe_ffn,
                              moe_param_specs)
from repro.models.ops import rms_norm
from repro.models.params import ParamSpec, ones_init, zeros_init
from repro.models.rwkv6 import (rwkv_channel_mix, rwkv_channel_specs,
                                rwkv_param_specs, rwkv_time_mix)

Array = jax.Array
ShardFn = Callable[[Array, Tuple[Optional[str], ...]], Array]


def _identity_shard(x: Array, logical: Tuple[Optional[str], ...]) -> Array:
    return x


class ModelContext:
    """Runtime knobs threaded through forwards (not traced)."""

    def __init__(self, *, compute_dtype=jnp.bfloat16, q_chunk: int = 2048,
                 shard: ShardFn = _identity_shard, mamba_chunk: int = 256,
                 rwkv_chunk: int = 16, attn_impl: str = "xla",
                 decode_cache_dtype=None, full_cache_window: bool = False,
                 mesh=None, data_axis: str = "data",
                 model_axis: str = "model",
                 moe_dispatch: str = "grouped",
                 moe_impl: Optional[str] = None):
        self.compute_dtype = compute_dtype
        self.q_chunk = q_chunk
        self.shard = shard
        self.mamba_chunk = mamba_chunk
        self.rwkv_chunk = rwkv_chunk
        self.attn_impl = attn_impl
        self.decode_cache_dtype = decode_cache_dtype  # None -> compute dtype
        # keep absolute (non-ring) KV slots even for sliding-window archs;
        # paged serving scatters prefill caches into append-only pages and
        # relies on the attention mask (not the ring) to bound the window
        self.full_cache_window = full_cache_window
        # serving mesh: when set, the paged kernel wrappers shard_map over
        # (data_axis, model_axis) so each shard streams its local KV-head
        # slice of the page pool (see kernels/ops.py)
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        # serving MoE dispatch: "grouped" (sort-based dropless through the
        # m-grouped GEMM kernel; the default) or "capacity" (the legacy
        # dense dropless buffer). Training forwards always use capacity
        # dispatch. moe_impl=None derives the kernel impl from attn_impl.
        self.moe_dispatch = moe_dispatch
        self.moe_impl = moe_impl

    @property
    def cache_dtype(self):
        return self.decode_cache_dtype or self.compute_dtype

    def moe_kwargs(self) -> Dict[str, Any]:
        """Serving-path moe_ffn kwargs for this context (dropless)."""
        if self.moe_dispatch != "grouped":
            return {"dropless": True}
        impl = self.moe_impl or {"pallas": "pallas",
                                 "pallas_interpret": "interpret"}.get(
                                     self.attn_impl, "ref")
        return {"dispatch": "grouped", "impl": impl, "mesh": self.mesh,
                "expert_axis": self.data_axis}


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def attn_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"),
                                init=zeros_init())
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                init=zeros_init())
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"),
                                init=zeros_init())
    return specs


def sublayer_specs(cfg: ModelConfig, idx: int) -> Dict[str, Any]:
    kind = cfg.sublayer_kinds()[idx]
    d = cfg.d_model
    out: Dict[str, Any] = {
        "ln1": ParamSpec((d,), ("embed",), init=ones_init()),
        "ln2": ParamSpec((d,), ("embed",), init=ones_init()),
    }
    if kind == "attn":
        out["core"] = attn_param_specs(cfg)
    elif kind == "mamba":
        out["core"] = mamba_param_specs(cfg)
    elif kind == "rwkv":
        out["core"] = rwkv_param_specs(cfg)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        out["mlp"] = rwkv_channel_specs(cfg)
    elif cfg.sublayer_has_moe(idx):
        out["mlp"] = moe_param_specs(cfg)
    else:
        out["mlp"] = dense_ffn_specs(cfg)
    return out


def block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {f"sl{i}": sublayer_specs(cfg, i) for i in range(cfg.block_len)}


def stack_specs(specs: Any, n: int) -> Any:
    """Add a leading stacking dim (logical axis None) to every leaf."""

    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n, *s.shape), (None, *s.logical), s.init, s.dtype)

    return jax.tree.map(stack, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Forward (training / no cache)
# ---------------------------------------------------------------------------


def _project_qkv(p: Dict[str, Array], x: Array, cfg: ModelConfig,
                 dtype) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return q, k, v


def attn_forward(p: Dict[str, Array], x: Array, cfg: ModelConfig,
                 ctx: ModelContext,
                 positions: Optional[Array] = None,
                 mrope_positions: Optional[Array] = None,
                 attn_type: Optional[str] = None) -> Array:
    b, s, _ = x.shape
    dtype = ctx.compute_dtype
    q, k, v = _project_qkv(p, x, cfg, dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k = apply_positional(q, k, cfg, positions, mrope_positions)
    q = ctx.shard(q, ("batch", "seq", "heads", None))
    k = ctx.shard(k, ("batch", "seq", "kv_heads", None))
    v = ctx.shard(v, ("batch", "seq", "kv_heads", None))
    out = full_attention(q, k, v, cfg, q_chunk=ctx.q_chunk,
                         attn_type=attn_type, impl=ctx.attn_impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def sublayer_forward(p: Dict[str, Any], x: Array, cfg: ModelConfig,
                     ctx: ModelContext, idx: int,
                     mrope_positions: Optional[Array] = None
                     ) -> Tuple[Array, Dict[str, Array]]:
    kind = cfg.sublayer_kinds()[idx]
    dtype = ctx.compute_dtype
    aux: Dict[str, Array] = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        core = attn_forward(p["core"], h, cfg, ctx,
                            mrope_positions=mrope_positions)
    elif kind == "mamba":
        core = mamba_forward(p["core"], h, cfg, dtype,
                             chunk=ctx.mamba_chunk)
    else:  # rwkv
        core = rwkv_time_mix(p["core"], h, cfg, dtype, chunk=ctx.rwkv_chunk)
    x = x + core
    x = ctx.shard(x, ("batch", "act_seq", "embed"))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        mlp = rwkv_channel_mix(p["mlp"], h, cfg, dtype)
    elif cfg.sublayer_has_moe(idx):
        mlp, aux = moe_ffn(p["mlp"], h, cfg, dtype, shard=ctx.shard)
    else:
        mlp = dense_ffn(p["mlp"], h, cfg, dtype)
    x = x + mlp
    x = ctx.shard(x, ("batch", "act_seq", "embed"))
    return x, aux


def block_forward(block_params: Dict[str, Any], x: Array, cfg: ModelConfig,
                  ctx: ModelContext,
                  mrope_positions: Optional[Array] = None
                  ) -> Tuple[Array, Dict[str, Array]]:
    aux_total: Dict[str, Array] = {}
    for i in range(cfg.block_len):
        x, aux = sublayer_forward(block_params[f"sl{i}"], x, cfg, ctx, i,
                                  mrope_positions)
        for key, val in aux.items():
            aux_total[key] = aux_total.get(key, 0.0) + val
    return x, aux_total


# ---------------------------------------------------------------------------
# Caches (prefill / decode)
# ---------------------------------------------------------------------------


def sublayer_cache_spec(cfg: ModelConfig, idx: int, batch: int,
                        window: int, ctx: ModelContext) -> Dict[str, Any]:
    kind = cfg.sublayer_kinds()[idx]
    hd = cfg.resolved_head_dim
    cdt = ctx.cache_dtype
    if kind == "attn":
        w = window
        if cfg.sliding_window is not None and not ctx.full_cache_window:
            w = min(window, cfg.sliding_window)
        return {
            "k": jax.ShapeDtypeStruct((batch, w, cfg.n_kv_heads, hd), cdt),
            "v": jax.ShapeDtypeStruct((batch, w, cfg.n_kv_heads, hd), cdt),
        }
    if kind == "mamba":
        return {
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.ssm_conv_width - 1, cfg.d_inner),
                ctx.compute_dtype),
            "ssm": jax.ShapeDtypeStruct(
                (batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
        }
    # rwkv
    return {
        "tok": jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                    ctx.compute_dtype),
        "wkv": jax.ShapeDtypeStruct(
            (batch, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
            jnp.float32),
        "cm_tok": jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                       ctx.compute_dtype),
    }


CACHE_LOGICAL = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "conv": ("batch", None, "mlp"),
    "ssm": ("batch", "mlp", None),
    "tok": ("batch", None, "embed"),
    "wkv": ("batch", "heads", None, None),
    "cm_tok": ("batch", None, "embed"),
    "xk": ("batch", None, "kv_heads", None),
    "xv": ("batch", None, "kv_heads", None),
}


def block_cache_spec(cfg: ModelConfig, batch: int, window: int,
                     ctx: ModelContext) -> Dict[str, Any]:
    return {f"sl{i}": sublayer_cache_spec(cfg, i, batch, window, ctx)
            for i in range(cfg.block_len)}


def init_cache(spec: Any) -> Any:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


# -- prefill: run full-sequence forward, produce filled caches --------------


def sublayer_prefill(p, x, cache, cfg: ModelConfig, ctx: ModelContext, idx,
                     mrope_positions=None, seq_mask=None):
    """Like sublayer_forward but writes the cache. x: (B,S,D).

    ``seq_mask`` (B,S) marks live positions when the server front-pads a
    prompt to a bucketed length (state families only): with zeroed
    embeddings the residual stream is exactly 0 through the pad prefix
    (every projection here is bias-free and every core output is gated
    by a zero), so masking the one biased intermediate — mamba's conv —
    keeps the recurrent state untouched until the first live token."""
    kind = cfg.sublayer_kinds()[idx]
    dtype = ctx.compute_dtype
    b, s, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        if seq_mask is not None:
            raise ValueError(
                "seq_mask (front padding) requires a state-family stack; "
                "attention positions would shift")
        q, k, v = _project_qkv(p["core"], h, cfg, dtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q, k = apply_positional(q, k, cfg, positions, mrope_positions)
        out = full_attention(q, k, v, cfg, q_chunk=ctx.q_chunk)
        core = jnp.einsum("bshk,hkd->bsd", out, p["core"]["wo"].astype(dtype))
        w = cache["k"].shape[1]
        if w >= s:
            newk = jnp.zeros_like(cache["k"]).at[:, :s].set(
                k.astype(ctx.cache_dtype))
            newv = jnp.zeros_like(cache["v"]).at[:, :s].set(
                v.astype(ctx.cache_dtype))
        else:  # keep last w (ring start aligned so slot = pos % w)
            start = s - w
            shift = start % w
            tailk = jnp.roll(k[:, start:], shift, axis=1)
            tailv = jnp.roll(v[:, start:], shift, axis=1)
            newk = tailk.astype(ctx.cache_dtype)
            newv = tailv.astype(ctx.cache_dtype)
        new_cache = {"k": newk, "v": newv}
    elif kind == "mamba":
        core, (conv, ssm) = mamba_forward(
            p["core"], h, cfg, dtype, chunk=ctx.mamba_chunk,
            return_state=True, seq_mask=seq_mask)
        new_cache = {"conv": conv, "ssm": ssm}
    else:
        core, (tok, wkv) = rwkv_time_mix(
            p["core"], h, cfg, dtype, chunk=ctx.rwkv_chunk,
            return_state=True)
        new_cache = {"tok": tok, "wkv": wkv}
    x = x + core
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        mlp, cm_tok = rwkv_channel_mix(p["mlp"], h, cfg, dtype,
                                       return_state=True)
        new_cache["cm_tok"] = cm_tok
    elif cfg.sublayer_has_moe(idx):
        mlp, _ = moe_ffn(p["mlp"], h, cfg, dtype, shard=ctx.shard,
                         **ctx.moe_kwargs())
    else:
        mlp = dense_ffn(p["mlp"], h, cfg, dtype)
    x = x + mlp
    x = ctx.shard(x, ("batch", "act_seq", "embed"))
    return x, new_cache


# -- decode: one token against caches ---------------------------------------


def sublayer_decode(p, x, cache, pos, cfg: ModelConfig, ctx: ModelContext,
                    idx, mrope_positions=None):
    """x: (B,1,D); pos: (B,) valid-token count BEFORE this token.

    ``pos`` is per-request: a continuous-batching server decodes requests
    of different lengths in one lockstep batch, so each row writes its own
    ring slot and masks its own validity window."""
    kind = cfg.sublayer_kinds()[idx]
    dtype = ctx.compute_dtype
    b = x.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        q, k, v = _project_qkv(p["core"], h, cfg, dtype)
        q, k = apply_positional(q, k, cfg, pos[:, None], mrope_positions)
        w = cache["k"].shape[1]
        bidx = jnp.arange(b)
        slot = pos % w  # (B,) per-request ring slot
        newk = cache["k"].at[bidx, slot].set(
            k[:, 0].astype(ctx.cache_dtype))
        newv = cache["v"].at[bidx, slot].set(
            v[:, 0].astype(ctx.cache_dtype))
        out = decode_attention(q, newk.astype(dtype), newv.astype(dtype),
                               pos + 1, cfg)
        core = jnp.einsum("bshk,hkd->bsd", out,
                          p["core"]["wo"].astype(dtype))
        new_cache = {"k": newk, "v": newv}
    elif kind == "mamba":
        core, (conv, ssm) = mamba_decode_step(
            p["core"], h, (cache["conv"], cache["ssm"]), cfg, dtype)
        new_cache = {"conv": conv, "ssm": ssm}
    else:
        core, (tok, wkv) = rwkv_time_mix(
            p["core"], h, cfg, dtype, chunk=1,
            init_state=(cache["tok"], cache["wkv"]), return_state=True)
        new_cache = {"tok": tok, "wkv": wkv}
    x = x + core
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        mlp, cm_tok = rwkv_channel_mix(p["mlp"], h, cfg, dtype,
                                       prev=cache["cm_tok"],
                                       return_state=True)
        new_cache["cm_tok"] = cm_tok
    elif cfg.sublayer_has_moe(idx):
        mlp, _ = moe_ffn(p["mlp"], h, cfg, dtype, shard=ctx.shard,
                         **ctx.moe_kwargs())
    else:
        mlp = dense_ffn(p["mlp"], h, cfg, dtype)
    x = x + mlp
    return x, new_cache


def block_prefill(block_params, x, cache, cfg, ctx, mrope_positions=None,
                  seq_mask=None):
    new_cache = {}
    for i in range(cfg.block_len):
        x, new_cache[f"sl{i}"] = sublayer_prefill(
            block_params[f"sl{i}"], x, cache[f"sl{i}"], cfg, ctx, i,
            mrope_positions, seq_mask)
    return x, new_cache


def block_decode(block_params, x, cache, pos, cfg, ctx,
                 mrope_positions=None):
    new_cache = {}
    for i in range(cfg.block_len):
        x, new_cache[f"sl{i}"] = sublayer_decode(
            block_params[f"sl{i}"], x, cache[f"sl{i}"], pos, cfg, ctx, i,
            mrope_positions)
    return x, new_cache


# -- dense span decode: T consecutive tokens against per-slot caches --------
#
# The dense-backend counterpart of the paged span path, and the datapath
# behind *chunked prefill* for hybrid (attention + state) stacks: a prompt
# is processed in fixed-size spans at absolute positions, so attention
# never needs front padding to bucket (positions are explicit), while the
# recurrent state of mamba/rwkv sublayers threads through the chunks.
# ``live`` marks real positions: a right-aligned prompt front-pads only its
# FIRST chunk, and dead positions are proven inert — their embeddings are
# zeroed by the caller, their cache writes are dropped, and every sublayer
# output is re-masked so the residual stream stays exactly 0 there (the
# recurrent state passes through a dead prefix untouched; see
# mamba_forward's seq_mask contract).


def sublayer_decode_span(p, x, cache, pos, live, cfg: ModelConfig,
                         ctx: ModelContext, idx, mrope_positions=None):
    """T-token span decode against dense per-slot caches (all families).

    x: (B,T,D) at absolute positions ``pos .. pos+T-1`` (already zeroed
    at dead positions); live: (B,T) bool. Attention caches must be
    append-only views (window >= total length — no ring wrap): k/v write
    at their absolute slot, dead writes are dropped.
    ``mrope_positions`` (3,B,T): explicit multimodal rope rows for this
    span (None = text default, broadcast from the absolute positions)."""
    kind = cfg.sublayer_kinds()[idx]
    dtype = ctx.compute_dtype
    b, t, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        q, k, v = _project_qkv(p["core"], h, cfg, dtype)
        posn = pos[:, None] + jnp.arange(t)[None, :]  # (B, T)
        q, k = apply_positional(q, k, cfg, posn, mrope_positions)
        w = cache["k"].shape[1]
        bidx = jnp.arange(b)[:, None]
        # dead positions write out of bounds and are dropped
        slot = jnp.where(live, posn, w)
        newk = cache["k"].at[bidx, slot].set(
            k.astype(ctx.cache_dtype), mode="drop")
        newv = cache["v"].at[bidx, slot].set(
            v.astype(ctx.cache_dtype), mode="drop")
        out = decode_span_attention(q, newk.astype(dtype),
                                    newv.astype(dtype), pos, cfg)
        core = jnp.einsum("bshk,hkd->bsd", out,
                          p["core"]["wo"].astype(dtype))
        new_cache = {"k": newk, "v": newv}
    elif kind == "mamba":
        core, (conv, ssm) = mamba_forward(
            p["core"], h, cfg, dtype, chunk=ctx.mamba_chunk,
            init_state=(cache["conv"], cache["ssm"]), return_state=True,
            seq_mask=live)
        new_cache = {"conv": conv, "ssm": ssm}
    else:  # rwkv
        core, (tok, wkv) = rwkv_time_mix(
            p["core"], h, cfg, dtype, chunk=ctx.rwkv_chunk,
            init_state=(cache["tok"], cache["wkv"]), return_state=True)
        new_cache = {"tok": tok, "wkv": wkv}
    # dead positions must stay exactly 0 in the residual stream: a dead
    # query's attention output is garbage (all-masked softmax) and would
    # otherwise leak into the next sublayer's conv window
    core = jnp.where(live[..., None], core, 0.0).astype(dtype)
    x = x + core
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        mlp, cm_tok = rwkv_channel_mix(p["mlp"], h, cfg, dtype,
                                       prev=cache["cm_tok"],
                                       return_state=True)
        new_cache["cm_tok"] = cm_tok
    elif cfg.sublayer_has_moe(idx):
        mlp, _ = moe_ffn(p["mlp"], h, cfg, dtype, shard=ctx.shard,
                         **ctx.moe_kwargs())
    else:
        mlp = dense_ffn(p["mlp"], h, cfg, dtype)
    x = x + jnp.where(live[..., None], mlp, 0.0).astype(dtype)
    return x, new_cache


def block_decode_span(block_params, x, cache, pos, live, cfg, ctx,
                      mrope_positions=None):
    new_cache = {}
    for i in range(cfg.block_len):
        x, new_cache[f"sl{i}"] = sublayer_decode_span(
            block_params[f"sl{i}"], x, cache[f"sl{i}"], pos, live, cfg,
            ctx, i, mrope_positions)
    return x, new_cache


# -- paged decode: block/paged KV cache (serving) ---------------------------
#
# Pages are a shared pool per layer: k/v of shape (num_pages, page_size,
# KV, D), plus page-aligned scale pages (num_pages, page_size, KV) when the
# cache dtype is int8 — scale pages DMA through the same scalar-prefetched
# page table as the KV pages, so the Pallas kernels dequantize in VMEM and
# quantized caches never pay a gather materialization. A request owns a
# list of page ids (its ``page_table`` row, padded with the reserved trash
# page 0); token ``p`` lives in page ``table[p // page_size]`` at slot
# ``p % page_size``. Only attention sublayers have paged state —
# state-space/RWKV layers carry O(1) state and gain nothing from paging.


def paged_quantize(x: Array, dtype) -> Tuple[Array, Optional[Array]]:
    """Per-(token, kv-head) symmetric int8 quantization hook.

    x: (..., KV, D). Returns (stored, scale or None); scale shape
    (..., KV) in bf16 — the storage dtype of the scale pages — and the
    values are quantized against that rounded scale so dequantization
    inverts exactly."""
    if dtype != jnp.int8:
        return x.astype(dtype), None
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-8) / 127.0
    scale = scale.astype(jnp.bfloat16)
    q = jnp.clip(jnp.round(x / scale[..., None].astype(jnp.float32)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def paged_dequantize(x: Array, scale: Optional[Array], dtype) -> Array:
    if scale is None:
        return x.astype(dtype)
    return (x.astype(jnp.float32) * scale[..., None]).astype(dtype)


def paged_sublayer_cache_spec(cfg: ModelConfig, num_pages: int,
                              page_size: int, ctx: ModelContext
                              ) -> Dict[str, Any]:
    hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
    cdt = ctx.cache_dtype
    spec = {
        "k": jax.ShapeDtypeStruct((num_pages, page_size, kv, hd), cdt),
        "v": jax.ShapeDtypeStruct((num_pages, page_size, kv, hd), cdt),
    }
    if cdt == jnp.int8:
        # bf16 scale pages: ample precision for a max-abs/127 scale, and
        # the pool stays well under half the bf16 cache's bytes — the
        # capacity lever the int8 page stream exists for
        spec["k_scale"] = jax.ShapeDtypeStruct(
            (num_pages, page_size, kv), jnp.bfloat16)
        spec["v_scale"] = jax.ShapeDtypeStruct(
            (num_pages, page_size, kv), jnp.bfloat16)
    return spec


def paged_block_cache_spec(cfg: ModelConfig, num_pages: int, page_size: int,
                           ctx: ModelContext) -> Dict[str, Any]:
    kinds = set(cfg.sublayer_kinds())
    if kinds != {"attn"}:
        raise ValueError(
            f"paged KV serving requires a pure-attention stack, got {kinds}")
    return {f"sl{i}": paged_sublayer_cache_spec(cfg, num_pages, page_size,
                                                ctx)
            for i in range(cfg.block_len)}


# per-layer page-pool logical axes: pool and scale pages shard on the KV
# head axis (over "model"); page/slot axes stay replicated so the host page
# table addresses every shard identically
PAGE_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "k": (None, None, "kv_heads", None),
    "v": (None, None, "kv_heads", None),
    "k_scale": (None, None, "kv_heads"),
    "v_scale": (None, None, "kv_heads"),
}


def _constrain_pages(pages: Dict[str, Array],
                     ctx: ModelContext) -> Dict[str, Array]:
    """Pin freshly-written page pools to their logical sharding so scatter
    updates (and jit donation) keep the KV-head partition stable."""
    return {name: ctx.shard(arr, PAGE_LOGICAL[name])
            for name, arr in pages.items()}


def _paged_gather(pages: Dict[str, Array], page_table: Array, dtype
                  ) -> Tuple[Array, Array]:
    """Materialize each request's KV view: (B, M*P, KV, D) in ``dtype``."""
    _, p, kv, hd = pages["k"].shape
    b, m = page_table.shape
    ks, vs = pages.get("k_scale"), pages.get("v_scale")
    kg = paged_dequantize(pages["k"][page_table],
                          None if ks is None else ks[page_table], dtype)
    vg = paged_dequantize(pages["v"][page_table],
                          None if vs is None else vs[page_table], dtype)
    shape = (b, m * p, kv, hd)
    return kg.reshape(shape), vg.reshape(shape)


def sublayer_decode_paged(p, x, pages, page_table, pos, cfg: ModelConfig,
                          ctx: ModelContext, idx):
    """One-token decode against the paged pool. x: (B,1,D); pos: (B,)."""
    dtype = ctx.compute_dtype
    b = x.shape[0]
    page_size = pages["k"].shape[1]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p["core"], h, cfg, dtype)
    q, k = apply_positional(q, k, cfg, pos[:, None], None)
    bidx = jnp.arange(b)
    pid = page_table[bidx, pos // page_size]  # (B,) owning page
    slot = pos % page_size
    kq, ks = paged_quantize(k[:, 0], ctx.cache_dtype)
    vq, vs = paged_quantize(v[:, 0], ctx.cache_dtype)
    new_pages = dict(pages)
    new_pages["k"] = pages["k"].at[pid, slot].set(kq)
    new_pages["v"] = pages["v"].at[pid, slot].set(vq)
    if ks is not None:
        new_pages["k_scale"] = pages["k_scale"].at[pid, slot].set(ks)
        new_pages["v_scale"] = pages["v_scale"].at[pid, slot].set(vs)
    new_pages = _constrain_pages(new_pages, ctx)
    if ctx.attn_impl in ("pallas", "pallas_interpret"):
        # stream pages straight through the scalar-prefetch Pallas kernel
        # — no HBM materialization of a contiguous per-request cache.
        # int8 pages stream natively: the (N, P, KV) scale pages ride the
        # same table entry and dequantize in VMEM, so quantized caches
        # read half the bytes per token instead of paying a gather.
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(
            q[:, 0], new_pages["k"], new_pages["v"], page_table, pos + 1,
            k_scale=new_pages.get("k_scale"),
            v_scale=new_pages.get("v_scale"),
            impl=("interpret" if ctx.attn_impl == "pallas_interpret"
                  else "pallas"),
            window=cfg.sliding_window, mesh=ctx.mesh,
            data_axis=ctx.data_axis, model_axis=ctx.model_axis)[:, None]
    else:
        # jnp gather-dequant oracle (the correctness contract for the
        # kernel route; materializes a contiguous per-request view)
        kg, vg = _paged_gather(new_pages, page_table, dtype)
        out = decode_attention(q, kg, vg, pos + 1, cfg)
    core = jnp.einsum("bshk,hkd->bsd", out, p["core"]["wo"].astype(dtype))
    x = x + core
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.sublayer_has_moe(idx):
        mlp, _ = moe_ffn(p["mlp"], h, cfg, dtype, shard=ctx.shard,
                         **ctx.moe_kwargs())
    else:
        mlp = dense_ffn(p["mlp"], h, cfg, dtype)
    x = x + mlp
    return x, new_pages


def block_decode_paged(block_params, x, pages, page_table, pos, cfg, ctx):
    new_pages = {}
    for i in range(cfg.block_len):
        x, new_pages[f"sl{i}"] = sublayer_decode_paged(
            block_params[f"sl{i}"], x, pages[f"sl{i}"], page_table, pos,
            cfg, ctx, i)
    return x, new_pages


# -- paged span decode: T consecutive tokens in one batched call ------------
#
# The datapath behind speculative decoding, prefix-cache suffix prefill,
# AND chunked cold prefill (every paged serving path is a page-stream now):
# a span of T tokens per request is scored in ONE paged-attention call —
# the span's k/v are scattered into the pages first (append-only), then
# query t attends causally through absolute position pos + t. Rolling back
# rejected draft tokens is just a position rewind: their k/v stay in the
# pool as garbage beyond the validity frontier and are overwritten before
# the frontier ever reaches them (the paper's hardware-replay framing —
# a deterministic datapath plus a replayable frontier beats bespoke undo).


def sublayer_decode_span_paged(p, x, pages, page_table, pos, live,
                               cfg: ModelConfig, ctx: ModelContext, idx,
                               mrope_positions=None):
    """T-token span decode against the paged pool.

    x: (B,T,D) at absolute positions ``pos .. pos+T-1``; live: (B,T)
    bool — False marks padded span slots whose writes are routed to the
    trash page (suffix prefills pad to a bucketed compile length).
    ``mrope_positions`` (3,B,T): explicit multimodal rope rows for the
    span (None = text default)."""
    dtype = ctx.compute_dtype
    b, t, _ = x.shape
    page_size = pages["k"].shape[1]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p["core"], h, cfg, dtype)
    posn = pos[:, None] + jnp.arange(t)[None, :]  # (B, T)
    q, k = apply_positional(q, k, cfg, posn, mrope_positions)
    bidx = jnp.arange(b)[:, None]
    # page-table reads beyond the row clamp; dead slots write to trash 0
    pid = jnp.where(live, page_table[bidx, posn // page_size], 0)
    slot = posn % page_size
    kq, ks = paged_quantize(k, ctx.cache_dtype)  # (B, T, KV, D)
    vq, vs = paged_quantize(v, ctx.cache_dtype)
    new_pages = dict(pages)
    new_pages["k"] = pages["k"].at[pid, slot].set(kq)
    new_pages["v"] = pages["v"].at[pid, slot].set(vq)
    if ks is not None:
        new_pages["k_scale"] = pages["k_scale"].at[pid, slot].set(ks)
        new_pages["v_scale"] = pages["v_scale"].at[pid, slot].set(vs)
    new_pages = _constrain_pages(new_pages, ctx)
    if ctx.attn_impl in ("pallas", "pallas_interpret"):
        # same page stream as single-token decode: int8 scale pages DMA
        # through the table, dequantize in VMEM — no gather oracle
        from repro.kernels import ops as kops
        out = kops.paged_decode_span_attention(
            q, new_pages["k"], new_pages["v"], page_table, pos,
            k_scale=new_pages.get("k_scale"),
            v_scale=new_pages.get("v_scale"),
            impl=("interpret" if ctx.attn_impl == "pallas_interpret"
                  else "pallas"),
            window=cfg.sliding_window, mesh=ctx.mesh,
            data_axis=ctx.data_axis, model_axis=ctx.model_axis)
    else:
        kg, vg = _paged_gather(new_pages, page_table, dtype)
        out = decode_span_attention(q, kg, vg, pos, cfg)
    core = jnp.einsum("bshk,hkd->bsd", out, p["core"]["wo"].astype(dtype))
    x = x + core
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.sublayer_has_moe(idx):
        mlp, _ = moe_ffn(p["mlp"], h, cfg, dtype, shard=ctx.shard,
                         **ctx.moe_kwargs())
    else:
        mlp = dense_ffn(p["mlp"], h, cfg, dtype)
    x = x + mlp
    return x, new_pages


def block_decode_span_paged(block_params, x, pages, page_table, pos, live,
                            cfg, ctx, mrope_positions=None):
    new_pages = {}
    for i in range(cfg.block_len):
        x, new_pages[f"sl{i}"] = sublayer_decode_span_paged(
            block_params[f"sl{i}"], x, pages[f"sl{i}"], page_table, pos,
            live, cfg, ctx, i, mrope_positions)
    return x, new_pages
