"""Elementary model ops: norms, activations, embeddings, RoPE/M-RoPE, loss.

All ops compute in fp32 where numerics matter (norms, softmax, loss) and
return the caller's compute dtype, mirroring TPU practice (bf16 MXU inputs,
fp32 accumulation — the paper's BF16 story).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


def embed_lookup(table: jax.Array, tokens: jax.Array,
                 compute_dtype) -> jax.Array:
    """Token embedding lookup. With a vocab-sharded table, XLA turns this
    into the SparseCore-style gather + cross-shard combine."""
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the rotary half-dim is split into
    sections (temporal, height, width), each rotated by its own position
    stream. positions: (3, ..., S). For text, all three streams coincide and
    M-RoPE reduces to RoPE."""
    half = x.shape[-1] // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} != half dim {half}")
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # build per-frequency position selector
    sel = []
    for i, s in enumerate(sections):
        sel.extend([i] * s)
    sel_arr = jnp.asarray(sel)  # (half,) in {0,1,2}
    # positions: (3, ..., S) -> (..., S, half)
    pos = jnp.moveaxis(positions, 0, -1).astype(jnp.float32)  # (..., S, 3)
    pos_per_freq = jnp.take(pos, sel_arr, axis=-1)  # (..., S, half)
    angles = pos_per_freq[..., None, :] * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(
        jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array,
    mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Stable token-mean cross entropy in fp32 over (possibly vocab-sharded)
    logits. Returns (mean_loss, token_count)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        count = jnp.maximum(mask.sum(), 1.0)
    else:
        count = jnp.asarray(nll.size, jnp.float32)
    return nll.sum() / count, count
