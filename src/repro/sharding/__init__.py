from repro.sharding.axes import (  # noqa: F401
    AxisRules,
    BASELINE_RULES,
    FSDP_RULES,
    logical_sharding,
    logical_constraint,
    resolve_spec,
)
