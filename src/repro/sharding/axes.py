"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every tensor in the framework is annotated with *logical* axis names
("batch", "embed", "mlp", ...). A rule set maps logical names to mesh axes;
``resolve_spec`` turns annotations into a ``PartitionSpec``, *dropping* any
mapping whose mesh-axis product does not evenly divide the tensor dimension
(replicating instead). This keeps every (arch x mesh) cell compiling — GQA
models with 2 or 4 KV heads simply replicate KV across the 16-way model axis
— and the dropped rules are reported so the roofline notes can call them out.

Rule sets:
  BASELINE_RULES — the paper-faithful scheme: pure DP across pods ("batch"
    over pod+data), Megacore tensor parallelism over "model" (heads / mlp /
    vocab), parameters replicated within the data axis (classic synchronous
    data-parallel training with all-reduce, as TPU v2-era training ran).
  FSDP_RULES — beyond-baseline: parameters additionally sharded over the
    data axis (ZeRO-3 / FSDP), required to fit the 1T-param arch; sequence
    activations sharded over "model" between blocks (sequence parallelism).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

LogicalAxes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Ordered mapping logical axis -> tuple of mesh axes."""

    name: str
    rules: Tuple[Tuple[str, Tuple[str, ...]], ...]

    def lookup(self, logical: str) -> Tuple[str, ...]:
        for key, mesh_axes in self.rules:
            if key == logical:
                return mesh_axes
        return ()


# Paper-faithful: DP over (pod, data); Megacore TP over model; params
# replicated across data (synchronous DP with gradient all-reduce).
BASELINE_RULES = AxisRules(
    name="baseline_dp_tp",
    rules=(
        ("batch", ("pod", "data")),
        ("seq", ()),
        ("embed", ()),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("head_dim", ()),
        ("mlp", ("model",)),
        ("vocab", ("model",)),
        ("expert", ("data",)),
        ("expert_mlp", ("model",)),
        ("exp_cap", ("data",)),  # capacity-parallel fallback for small E
        ("kv_seq", ()),
        ("conv", ()),
        ("state", ()),
    ),
)

# Beyond-paper: ZeRO-3-style extra parameter sharding (experts also over
# the pod axis), sequence parallelism for activations, and sequence-sharded
# KV caches (decode attention reduces over the model axis).
FSDP_RULES = AxisRules(
    name="fsdp_tp_sp",
    rules=(
        ("batch", ("pod", "data")),
        ("seq", ()),
        ("act_seq", ("model",)),  # sequence parallelism for activations
        ("embed", ()),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("head_dim", ()),
        ("mlp", ("model",)),
        ("vocab", ("model",)),
        ("expert", ("data", "pod")),
        ("expert_mlp", ("model",)),
        ("exp_cap", ("data",)),  # capacity-parallel fallback for small E
        ("kv_seq", ("model",)),  # decode KV sequence-sharded over model
        ("conv", ()),
        ("state", ()),
    ),
)

# Sequence-parallel-only: weights replicated, activations sharded on the
# sequence axis over "model". Wins for attention-free stacks (RWKV): all
# channel math is token-local, so the only collectives are token-shift
# halos and tiny chunk-state combines — vs TP's per-projection activation
# reshards (measured 141 GiB/device/step on rwkv6 train_4k).
SP_RULES = AxisRules(
    name="sp_only",
    rules=(
        ("batch", ("pod", "data")),
        ("seq", ()),
        ("act_seq", ("model",)),
        ("embed", ()),
        ("heads", ()),
        ("kv_heads", ()),
        ("head_dim", ()),
        ("mlp", ()),
        ("vocab", ("model",)),
        ("expert", ("data",)),
        ("expert_mlp", ()),
        ("exp_cap", ()),
        ("kv_seq", ("model",)),
        ("conv", ()),
        ("state", ()),
    ),
)

# Pure synchronous data parallelism — the paper's TPU v2-era recipe (and
# its cross-pod recipe at Gemini scale): batch over EVERY mesh axis,
# weights replicated, one gradient all-reduce per step. The right scheme
# for small dense models where TP-16 activation reshards dwarf compute.
DP_RULES = AxisRules(
    name="dp_pure",
    rules=(
        ("batch", ("pod", "data", "model")),
        ("seq", ()),
        ("embed", ()),
        ("heads", ()),
        ("kv_heads", ()),
        ("head_dim", ()),
        ("mlp", ()),
        ("vocab", ()),
        ("expert", ()),
        ("expert_mlp", ()),
        ("exp_cap", ()),
        ("kv_seq", ()),
        ("conv", ()),
        ("state", ()),
    ),
)

RULE_SETS: Dict[str, AxisRules] = {
    r.name: r for r in (BASELINE_RULES, FSDP_RULES, SP_RULES, DP_RULES)
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(
    logical_axes: LogicalAxes,
    dims: Sequence[int],
    mesh: Mesh,
    rules: AxisRules,
    dropped: Optional[List[Tuple[str, int]]] = None,
) -> PartitionSpec:
    """Resolve logical annotations to a PartitionSpec for concrete ``dims``.

    A mapping is applied only if (a) every mesh axis exists in the mesh,
    (b) their product divides the dimension, and (c) no mesh axis is already
    used by an earlier dimension. Otherwise the dim is replicated and the
    drop recorded in ``dropped``.
    """
    if len(logical_axes) != len(dims):
        raise ValueError(
            f"logical axes {logical_axes} rank != shape {tuple(dims)}")
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    entries: List[Optional[Tuple[str, ...]]] = []
    for logical, dim in zip(logical_axes, dims):
        if logical is None:
            entries.append(None)
            continue
        mesh_axes = [a for a in rules.lookup(logical) if a in sizes]
        mesh_axes = [a for a in mesh_axes if a not in used]
        # largest subset of the mapping that divides the dim (greedy in
        # rule order; non-dividing axes are skipped, not fatal)
        chosen: List[str] = []
        prod = 1
        for a in mesh_axes:
            if dim % (prod * sizes[a]) == 0:
                chosen.append(a)
                prod *= sizes[a]
        if chosen:
            used.update(chosen)
            entries.append(tuple(chosen))
        else:
            if rules.lookup(logical) and dropped is not None:
                dropped.append((logical, dim))
            entries.append(None)
    # trim trailing Nones for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*[e if e is None else
                           (e[0] if len(e) == 1 else e) for e in entries])


def logical_sharding(
    logical_axes: LogicalAxes,
    dims: Sequence[int],
    mesh: Mesh,
    rules: AxisRules,
    dropped: Optional[List[Tuple[str, int]]] = None,
) -> NamedSharding:
    return NamedSharding(
        mesh, resolve_spec(logical_axes, dims, mesh, rules, dropped))


def logical_constraint(x: jax.Array, logical_axes: LogicalAxes, mesh: Mesh,
                       rules: AxisRules) -> jax.Array:
    """with_sharding_constraint via logical axes (shape-aware)."""
    spec = resolve_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def summarize_dropped(dropped: Sequence[Tuple[str, int]],
                      mesh: Mesh, rules: AxisRules) -> List[str]:
    """Dedupe and render the ``dropped`` list that resolve_spec appends to
    into human-readable fallback lines, e.g.
    ``kv_heads=2 not divisible by mesh axes ('model',)=4 -> replicated``.

    Serve engines report these once at construction so GQA KV replication
    (and any other silent divisibility fallback) is visible in logs and
    engine stats instead of being swallowed."""
    sizes = _mesh_axis_sizes(mesh)
    lines: List[str] = []
    for logical, dim in dict.fromkeys(dropped):  # dedupe, keep order
        axes = tuple(a for a in rules.lookup(logical) if a in sizes)
        prod = math.prod(sizes[a] for a in axes) if axes else 1
        lines.append(
            f"{logical}={dim} not divisible by mesh axes {axes}={prod}"
            " -> replicated")
    return lines


def tree_shardings(tree_logical, tree_shapes, mesh: Mesh, rules: AxisRules,
                   dropped: Optional[List[Tuple[str, int]]] = None):
    """Map a pytree of logical-axes tuples + matching pytree of shapes to a
    pytree of NamedShardings."""
    return jax.tree.map(
        lambda la, shp: logical_sharding(la, shp, mesh, rules, dropped),
        tree_logical, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
