"""Metrics registry: named counters, gauges, fixed-bucket histograms.

Design constraints, in order:

  * **Zero overhead when disabled.** A disabled registry hands every
    caller the same ``NULL_METRIC`` singleton whose mutators are
    no-ops; no per-metric state is ever allocated, ``snapshot()`` is
    ``{}``, and ``to_jsonl()`` writes nothing. Instrumented hot loops
    pay one attribute call on a do-nothing object.
  * **Legacy dict call sites keep working.** ``CounterDict`` is a
    mapping facade over registry counters with a fixed key set, so
    ``engine.counters["chunks"] += 1`` and bench-style
    ``engine.counters[k] = 0`` resets route into the registry without
    touching the ~40 existing call sites.
  * **Plain-data snapshots.** ``snapshot()`` returns JSON-ready dicts;
    ``to_jsonl(path)`` appends one timestamped snapshot per line.

``CATALOG`` below is the pinned metric vocabulary; docs_check verifies
every name in the docs/observability.md catalog table resolves here.
"""

from __future__ import annotations

import json
import time
from typing import Dict, Iterator, List, Mapping, MutableMapping, \
    Optional, Sequence, Tuple

Number = float

# Default histogram edges for wall-clock latencies (seconds): log-ish
# spacing from 1ms to 60s, the TTFT/TPOT range a serve SLO cares about.
DEFAULT_TIME_BUCKETS_S: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

# Queue-wait is measured in engine boundary steps, not seconds.
QUEUE_WAIT_BUCKETS_STEPS: Tuple[float, ...] = (
    0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)

# The pinned metric vocabulary: name -> (kind, unit, description).
# docs/observability.md's catalog table is generated from this set and
# scripts/docs_check.py greps doc-listed names back against this file.
CATALOG: Dict[str, Tuple[str, str, str]] = {
    # serve: engine work counters (the legacy ServeEngine.counters keys,
    # "serve_"-prefixed on the registry)
    "serve_prefills": ("counter", "requests", "prefill admissions"),
    "serve_chunks": ("counter", "chunks", "device decode chunks launched"),
    "serve_decode_steps": ("counter", "steps", "decode steps executed"),
    "serve_host_syncs": ("counter", "syncs", "host blocking device reads"),
    "serve_pertoken_steps": ("counter", "steps",
                             "legacy per-token loop steps"),
    "serve_pages_trimmed": ("counter", "pages", "KV pages trimmed"),
    "serve_suffix_prefills": ("counter", "requests",
                              "prefix-cache suffix prefills"),
    "serve_prompt_tokens": ("counter", "tokens", "prompt tokens submitted"),
    "serve_cached_prompt_tokens": ("counter", "tokens",
                                   "prompt tokens served from prefix cache"),
    "serve_spec_steps": ("counter", "steps", "speculative verify steps"),
    "serve_spec_tokens": ("counter", "tokens",
                          "tokens emitted by speculative steps"),
    "serve_prefill_span_calls": ("counter", "calls",
                                 "span-prefill invocations"),
    "serve_span_prefill_compiles": ("counter", "compiles",
                                    "paged span-prefill trace events"),
    "serve_span_prefill_dense_compiles": ("counter", "compiles",
                                          "dense span-prefill trace events"),
    # serve: disaggregation (the legacy disagg_stats keys)
    "serve_transfers": ("counter", "transfers", "prefill->decode handoffs"),
    "serve_transfer_pages": ("counter", "pages", "KV pages transferred"),
    "serve_transfer_bytes": ("counter", "bytes", "KV bytes transferred"),
    "serve_transfer_stall_boundaries": ("counter", "boundaries",
                                        "boundaries stalled on transfer"),
    "serve_decode_idle_boundaries": ("counter", "boundaries",
                                     "decode boundaries with no live slot"),
    "serve_boundaries": ("counter", "boundaries",
                         "scheduler boundaries observed"),
    "serve_prefill_depth_sum": ("counter", "depth",
                                "prefill queue depth, summed per boundary"),
    "serve_prefill_depth_peak": ("gauge-as-counter", "depth",
                                 "peak prefill queue depth"),
    "serve_decode_depth_sum": ("counter", "depth",
                               "decode occupancy, summed per boundary"),
    "serve_decode_depth_peak": ("gauge-as-counter", "depth",
                                "peak decode occupancy"),
    # serve: request SLO metrics
    "serve_requests_admitted": ("counter", "requests", "admissions"),
    "serve_requests_finished": ("counter", "requests", "completions"),
    "serve_requests_preempted": ("counter", "requests", "preemptions"),
    "serve_ttft_s": ("histogram", "s", "time to first token per request"),
    "serve_tpot_s": ("histogram", "s",
                     "time per output token per request (post-first)"),
    "serve_e2e_s": ("histogram", "s", "request ready->finish wall time"),
    "serve_queue_wait_steps": ("histogram", "steps",
                               "arrival->admission wait in boundary steps"),
    "serve_prefill_s": ("histogram", "s", "per-admission prefill wall time"),
    "serve_chunk_s": ("histogram", "s",
                      "per-chunk dispatch+sync wall time"),
    # serve: role time/token split (prefill vs decode)
    "serve_prefill_time_s": ("counter", "s", "total prefill wall time"),
    "serve_decode_time_s": ("counter", "s", "total decode wall time"),
    "serve_prefill_tokens": ("counter", "tokens",
                             "non-cached prompt tokens prefilled"),
    "serve_decode_tokens": ("counter", "tokens",
                            "tokens drained from decode chunks"),
    "serve_generated_tokens": ("counter", "tokens",
                               "tokens delivered to finished requests"),
    # serve: fault injection / detection / recovery (engine fault_stats)
    "serve_fault_worker_failures": ("counter", "failures",
                                    "injected prefill-worker failures"),
    "serve_fault_page_corruptions": ("counter", "pages",
                                     "injected KV page corruptions"),
    "serve_fault_pages_quarantined": ("counter", "pages",
                                      "corrupt pages CRC-detected and "
                                      "quarantined"),
    "serve_fault_transfer_drops": ("counter", "drops",
                                   "dropped prefill->decode transfers"),
    "serve_fault_stragglers": ("counter", "chunks",
                               "decode chunks hit by straggler delay"),
    "serve_fault_detections": ("counter", "events",
                               "fault events detected by the engine"),
    # serve: request replay + terminal failure
    "serve_retry_requeues": ("counter", "requests",
                             "fault replays re-queued with backoff"),
    "serve_retry_failures": ("counter", "requests",
                             "requests terminally failed (budget spent)"),
    # serve: SLO-aware admission shedding
    "serve_shed_requests": ("counter", "requests",
                            "requests shed at enqueue (TTFT unmeetable)"),
    "serve_shed_spec_chunks": ("counter", "chunks",
                               "chunks demoted from speculative decode "
                               "under queue pressure"),
    # train: resilient-trainer lifecycle
    "train_steps": ("counter", "steps", "effective (non-replay) steps"),
    "train_replayed_steps": ("counter", "steps",
                             "steps re-run after a restore"),
    "train_ckpt_saves": ("counter", "saves", "checkpoint snapshots issued"),
    "train_failures": ("counter", "failures", "injected cube failures"),
    "train_restores": ("counter", "restores", "checkpoint restores"),
    "train_step_s": ("histogram", "s", "per-step wall time"),
}


class Counter:
    """Monotonic-by-convention accumulator (bench code may reset it)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0

    def inc(self, v: Number = 1) -> None:
        self.value += v

    add = inc

    def set(self, v: Number) -> None:
        self.value = v


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def inc(self, v: Number = 1) -> None:
        self.value += v

    add = inc


class Histogram:
    """Fixed-bucket histogram: ``edges`` are upper bounds of the first
    ``len(edges)`` buckets plus an implicit overflow bucket; quantiles
    interpolate linearly inside the bucket, clamped to observed
    min/max."""

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str,
                 edges: Sequence[float] = DEFAULT_TIME_BUCKETS_S) -> None:
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError(f"histogram {name}: edges must be strictly "
                             f"increasing, got {edges!r}")
        self.name = name
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: Number) -> None:
        v = float(v)
        i = 0
        for e in self.edges:
            if v <= e:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0..1) from bucket counts."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.edges[i - 1] if i > 0 else self.min
                hi = self.edges[i] if i < len(self.edges) else self.max
                lo, hi = max(lo, self.min), min(hi, self.max)
                frac = (rank - seen) / c
                return lo + (hi - lo) * max(0.0, min(1.0, frac))
            seen += c
        return self.max

    def to_dict(self) -> Dict[str, object]:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.5),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "edges": list(self.edges),
            "buckets": list(self.counts),
        }


class _NullMetric:
    """Shared do-nothing metric handed out by a disabled registry: every
    mutator is a no-op, every reader returns zero."""

    __slots__ = ()
    name = "<null>"
    value: Number = 0
    count = 0
    sum = 0.0
    mean = 0.0

    def inc(self, v: Number = 1) -> None:
        pass

    add = inc
    set = inc
    observe = inc

    def quantile(self, q: float) -> float:
        return 0.0

    def to_dict(self) -> Dict[str, object]:
        return {}


NULL_METRIC = _NullMetric()


def _key(name: str, labels: Optional[Mapping[str, str]]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Registry of named metrics with optional label sets.

    ``counter()``/``gauge()``/``histogram()`` are get-or-create and
    return the live metric object — instrument construction once, then
    mutate the returned handle in hot loops (one dict lookup saved per
    event)."""

    def __init__(self, enabled: bool = True,
                 clock=time.time) -> None:
        self.enabled = enabled
        self.clock = clock
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, labels, factory):
        if not self.enabled:
            return NULL_METRIC
        key = _key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = factory(key)
        return m

    def counter(self, name: str,
                labels: Optional[Mapping[str, str]] = None) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str,
              labels: Optional[Mapping[str, str]] = None) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  edges: Sequence[float] = DEFAULT_TIME_BUCKETS_S,
                  labels: Optional[Mapping[str, str]] = None) -> Histogram:
        return self._get(name, labels, lambda k: Histogram(k, edges))

    def compile_event(self, name: str) -> None:
        """Record one *compilation* of a traced function.

        Trace-time semantics, pinned: call this ONLY from Python code
        that executes while jax traces the function (e.g. inside a
        jitted body). jax runs that Python once per compiled program
        variant, so the counter counts compilations — program-family
        cache hits do NOT re-execute the tracer and must not bump it.
        A retrace (new shape bucket, new donation pattern) legitimately
        counts again; calling this from regular eager code would
        double-count and is a bug at the call site."""
        self.counter(f"{name}_compiles").inc()

    def snapshot(self) -> Dict[str, object]:
        """Plain JSON-ready dict: scalars for counters/gauges, nested
        dicts for histograms. Disabled registry -> ``{}``."""
        out: Dict[str, object] = {}
        for key, m in sorted(self._metrics.items()):
            out[key] = m.to_dict() if isinstance(m, Histogram) else m.value
        return out

    def to_jsonl(self, path: str) -> None:
        """Append one timestamped snapshot line; no-op when disabled."""
        if not self.enabled:
            return
        with open(path, "a") as f:
            f.write(json.dumps({"t": float(self.clock()),
                                "metrics": self.snapshot()}) + "\n")

    def reset(self) -> None:
        self._metrics.clear()


class CounterDict(MutableMapping):
    """Mapping facade over registry counters with a fixed key set.

    Keeps legacy ``engine.counters["chunks"] += 1`` and bench-style
    ``engine.counters[k] = 0`` call sites working while the registry
    owns the numbers (under ``prefix + key`` names). Unknown keys raise
    — the key set is the pinned vocabulary, not an open dict."""

    def __init__(self, registry: MetricsRegistry, keys: Sequence[str],
                 prefix: str = "") -> None:
        self._c: Dict[str, object] = {
            k: registry.counter(prefix + k) for k in keys}

    def __getitem__(self, k: str) -> Number:
        return self._c[k].value

    def __setitem__(self, k: str, v: Number) -> None:
        self._c[k].set(v)

    def __delitem__(self, k: str) -> None:
        raise TypeError("CounterDict keys are fixed")

    def __iter__(self) -> Iterator[str]:
        return iter(self._c)

    def __len__(self) -> int:
        return len(self._c)

    def __repr__(self) -> str:
        return repr({k: m.value for k, m in self._c.items()})
