"""Unified telemetry: one metrics/tracing/steptrace vocabulary shared
by the serve engine, the resilient trainer, and the fleet simulator.

Three layers, all host-side (device programs are never touched, so an
instrumented engine stays token-identical to a bare one):

  * ``obs.metrics``   — named counters / gauges / fixed-bucket
    histograms behind a registry; zero-overhead when disabled;
    JSONL snapshots.
  * ``obs.trace``     — begin/end spans with pid/tid lanes and an
    injectable clock, serialized as Chrome-trace JSON. The fleet sim's
    ``TraceRecorder`` is a thin shim over ``SpanTracer``, so sim
    events, serve request lifecycles, and trainer step/replay events
    all merge into one timeline.
  * ``obs.steptrace`` — measured per-step/per-chunk durations with
    features (batch size, prefix hit, chunk kind); replayable through
    ``fleet.perf.StepTimeModel.from_trace``.
"""

from repro.obs.metrics import (CATALOG, CounterDict, MetricsRegistry,
                               NULL_METRIC)
from repro.obs.steptrace import StepEvent, StepTrace
from repro.obs.trace import (SpanTracer, merge_chrome_traces,
                             validate_chrome_trace)

__all__ = [
    "CATALOG",
    "CounterDict",
    "MetricsRegistry",
    "NULL_METRIC",
    "SpanTracer",
    "StepEvent",
    "StepTrace",
    "merge_chrome_traces",
    "validate_chrome_trace",
]
