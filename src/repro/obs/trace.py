"""Span tracing: begin/end spans with pid/tid lanes, an injectable
clock, and Chrome Trace Event Format serialization.

One schema for the whole repo: the fleet simulator's ``TraceRecorder``
(kept API-compatible below, re-exported from ``repro.fleet.trace``),
``ServeEngine`` request lifecycles, and ``ResilientTrainer`` step /
checkpoint / replay events all emit through a ``SpanTracer``, so a
single JSON file loads in chrome://tracing / Perfetto with sim jobs,
serve slots, and trainer steps as sibling process rows.

Timestamps: event methods accept an explicit ``ts`` (seconds — the
fleet sim passes simulated time); when omitted, the injectable
``clock`` is sampled and rebased so the first event sits at t=0.
Stored values follow the Chrome convention (microseconds).

``validate_chrome_trace`` is the tier-1 gate's checker: balanced and
properly nested B/E per (pid, tid) lane, monotonic lane timestamps,
non-negative X durations, required categories present.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

_US = 1e6


class SpanTracer:
    """Chrome-trace event sink with process/thread lanes.

    Disabled tracers record nothing and cost one attribute check per
    call, so hot loops can call unconditionally."""

    def __init__(self, clock=time.monotonic, enabled: bool = True) -> None:
        self.clock = clock
        self.enabled = enabled
        self.events: List[Dict[str, Any]] = []
        self._pids: Dict[str, int] = {}
        self._open: Dict[Tuple[int, int], List[str]] = {}
        self._t0: Optional[float] = None

    # -- lanes ---------------------------------------------------------------

    def process(self, name: str) -> int:
        """Get-or-register a process row; emits the ``process_name``
        metadata event on first sight. Returns 0 when disabled."""
        if not self.enabled:
            return 0
        if name not in self._pids:
            pid = len(self._pids)
            self._pids[name] = pid
            self.events.append({"ph": "M", "pid": pid,
                                "name": "process_name",
                                "args": {"name": name}})
        return self._pids[name]

    def thread(self, pid: int, tid: int, name: str) -> int:
        """Label a thread lane inside a process row."""
        if self.enabled:
            self.events.append({"ph": "M", "pid": pid, "tid": tid,
                                "name": "thread_name",
                                "args": {"name": name}})
        return tid

    # -- timestamps ----------------------------------------------------------

    def _ts_us(self, ts: Optional[float]) -> float:
        if ts is not None:
            return ts * _US
        now = self.clock()
        if self._t0 is None:
            self._t0 = now
        return (now - self._t0) * _US

    # -- emitters ------------------------------------------------------------

    def emit(self, ev: Dict[str, Any]) -> None:
        """Append a pre-built raw event (advanced callers: the fleet
        recorder's colored phases). No-op when disabled."""
        if self.enabled:
            self.events.append(ev)

    def begin(self, name: str, *, pid: int = 0, tid: int = 0,
              cat: str = "", args: Optional[Dict[str, Any]] = None,
              ts: Optional[float] = None) -> None:
        if not self.enabled:
            return
        ev: Dict[str, Any] = {"ph": "B", "pid": pid, "tid": tid,
                              "name": name, "ts": self._ts_us(ts)}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)
        self._open.setdefault((pid, tid), []).append(name)

    def end(self, *, pid: int = 0, tid: int = 0,
            ts: Optional[float] = None) -> None:
        if not self.enabled:
            return
        stack = self._open.get((pid, tid), [])
        name = stack.pop() if stack else "<unmatched>"
        self.events.append({"ph": "E", "pid": pid, "tid": tid,
                            "name": name, "ts": self._ts_us(ts)})

    @contextmanager
    def span(self, name: str, *, pid: int = 0, tid: int = 0,
             cat: str = "", args: Optional[Dict[str, Any]] = None
             ) -> Iterator[None]:
        self.begin(name, pid=pid, tid=tid, cat=cat, args=args)
        try:
            yield
        finally:
            self.end(pid=pid, tid=tid)

    def complete(self, name: str, dur_s: float, *, pid: int = 0,
                 tid: int = 0, cat: str = "",
                 args: Optional[Dict[str, Any]] = None,
                 ts: Optional[float] = None) -> None:
        """An X event; with ``ts`` omitted the span is assumed to end
        now, so its start is rebased ``dur_s`` ago."""
        if not self.enabled:
            return
        if ts is None:
            start_us = self._ts_us(None) - dur_s * _US
        else:
            start_us = ts * _US
        ev: Dict[str, Any] = {"ph": "X", "pid": pid, "tid": tid,
                              "name": name, "ts": start_us,
                              "dur": max(dur_s, 0.0) * _US}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, *, pid: int = 0, tid: int = 0,
                cat: str = "", scope: str = "g",
                args: Optional[Dict[str, Any]] = None,
                ts: Optional[float] = None) -> None:
        if not self.enabled:
            return
        ev: Dict[str, Any] = {"ph": "i", "s": scope, "pid": pid,
                              "tid": tid, "name": name,
                              "ts": self._ts_us(ts)}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: Dict[str, float], *,
                pid: int = 0, tid: int = 0,
                ts: Optional[float] = None) -> None:
        if not self.enabled:
            return
        self.events.append({"ph": "C", "pid": pid, "tid": tid,
                            "name": name, "ts": self._ts_us(ts),
                            "args": dict(values)})

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def validate_chrome_trace(doc: Dict[str, Any],
                          require_cats: Sequence[str] = ()
                          ) -> List[str]:
    """Structural checks on a Chrome-trace document; returns a list of
    problems (empty == valid).

    Checks: every event has ph/pid/name; non-metadata events carry a
    numeric ts; X durations are non-negative; B/E events per (pid, tid)
    lane are balanced, properly nested, and non-decreasing in time;
    every category in ``require_cats`` appears."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    stacks: Dict[Tuple[int, int], List[Tuple[str, float]]] = {}
    last_ts: Dict[Tuple[int, int], float] = {}
    cats = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        for field in ("ph", "pid", "name"):
            if field not in ev:
                problems.append(f"event {i}: missing {field!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i} ({ev.get('name')}): bad ts {ts!r}")
            continue
        if ev.get("cat"):
            cats.add(ev["cat"])
        lane = (ev.get("pid", 0), ev.get("tid", 0))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({ev.get('name')}): "
                                f"X dur {dur!r} not a non-negative number")
        elif ph == "B":
            if ts < last_ts.get(lane, float("-inf")):
                problems.append(f"event {i} ({ev.get('name')}): lane "
                                f"{lane} ts regressed {ts} < "
                                f"{last_ts[lane]}")
            last_ts[lane] = ts
            stacks.setdefault(lane, []).append((ev.get("name", "?"), ts))
        elif ph == "E":
            if ts < last_ts.get(lane, float("-inf")):
                problems.append(f"event {i} ({ev.get('name')}): lane "
                                f"{lane} ts regressed {ts} < "
                                f"{last_ts[lane]}")
            last_ts[lane] = ts
            stack = stacks.get(lane, [])
            if not stack:
                problems.append(f"event {i} ({ev.get('name')}): E "
                                f"without open B on lane {lane}")
            else:
                name, t_open = stack.pop()
                if ts < t_open:
                    problems.append(f"event {i}: span {name!r} on lane "
                                    f"{lane} ends before it begins")
    for lane, stack in stacks.items():
        for name, _ in stack:
            problems.append(f"unclosed span {name!r} on lane {lane}")
    missing = set(require_cats) - cats
    if missing:
        problems.append(f"missing categories: {sorted(missing)} "
                        f"(saw {sorted(cats)})")
    return problems


def merge_chrome_traces(docs: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Merge trace documents into one timeline, remapping pids so
    process rows from different sources never collide."""
    merged: List[Dict[str, Any]] = []
    base = 0
    for doc in docs:
        events = doc.get("traceEvents", [])
        pids = sorted({e.get("pid", 0) for e in events
                       if isinstance(e, dict)})
        remap = {p: base + i for i, p in enumerate(pids)}
        for ev in events:
            ev2 = dict(ev)
            ev2["pid"] = remap.get(ev.get("pid", 0), base)
            merged.append(ev2)
        base += max(len(pids), 1)
    return {"traceEvents": merged, "displayTimeUnit": "ms"}


# -- fleet-sim recorder (re-exported from repro.fleet.trace) -----------------

_POD_PID = 0  # kept for callers that imported the module constant
_PHASE_TID = 1

_COLORS = {
    "train": "good",
    "rework": "bad",
    "restore": "terrible",
    "detect": "yellow",
    "queued": "grey",
    "ckpt": "olive",
}


class TraceRecorder:
    """The fleet simulator's trace surface, now a shim over
    ``SpanTracer``: one process row per job (colored X phases at
    explicit simulated timestamps) plus a pod row of instants and
    counters. Pass a shared tracer to land sim events in the same
    timeline as serve/train spans; the default is a private one, which
    preserves the original standalone behavior byte-for-byte modulo
    metadata-event ordering."""

    def __init__(self, tracer: Optional[SpanTracer] = None) -> None:
        self.tracer = tracer if tracer is not None else SpanTracer()
        self._pod_pid = self.tracer.process("pod")

    @property
    def events(self) -> List[Dict[str, Any]]:
        return self.tracer.events

    def _pid(self, job: str) -> int:
        return self.tracer.process(f"job:{job}")

    def duration(self, job: str, phase: str, t0_s: float, dur_s: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A complete event on the job's row; zero-length phases (async
        checkpoint marks) become instants so they stay visible."""
        ev: Dict[str, Any] = {
            "pid": self._pid(job), "tid": _PHASE_TID, "name": phase,
            "ts": t0_s * _US, "cat": "fleet",
        }
        if _COLORS.get(phase):
            ev["cname"] = _COLORS[phase]
        if args:
            ev["args"] = args
        if dur_s <= 0.0:
            ev.update(ph="i", s="t")
        else:
            ev.update(ph="X", dur=dur_s * _US)
        self.tracer.emit(ev)

    def instant(self, name: str, t_s: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        self.tracer.instant(name, pid=self._pod_pid, tid=0, cat="pod",
                            scope="g", args=args, ts=t_s)

    def counter(self, name: str, t_s: float,
                values: Dict[str, float]) -> None:
        self.tracer.counter(name, values, pid=self._pod_pid, tid=0,
                            ts=t_s)

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        return self.tracer.chrome_trace()

    def write(self, path: str) -> None:
        self.tracer.write(path)
