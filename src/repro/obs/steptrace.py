"""Measured step-time traces: the replayable artifact between real
runs and the fleet simulator.

A ``StepTrace`` records one event per executed unit of work — a train
step, a decode chunk, a prefill — with its measured wall duration and
a small feature dict (batch size, token counts, prefix-hit, chunk
kind). ``fleet.perf.StepTimeModel.from_trace`` turns the artifact into
a step-time model, so the simulator can run on measured serve/train
traces instead of the analytic roofline (ROADMAP item 3), and every
future kernel PR gets a predicted-vs-measured seam.

Serialization is a plain JSON document (``SCHEMA`` below) so traces
survive process boundaries — the tier-1 gate records one in the serve
smoke subprocess and replays it through the sim in another.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence

SCHEMA = "repro.obs.steptrace/v1"

# Pinned event kinds. "step"/"decode"/"spec_decode" are effective work
# (what a step-time model should learn from); "replay" is rework after
# a restore; "prefill"/"ckpt" are role-specific phases.
KINDS = ("step", "replay", "prefill", "decode", "spec_decode", "ckpt")

# The kinds from_trace treats as one effective step by default.
EFFECTIVE_KINDS = ("step", "decode", "spec_decode")


@dataclasses.dataclass(frozen=True)
class StepEvent:
    """One executed unit of work with its measured duration."""

    kind: str
    duration_s: float
    features: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "duration_s": self.duration_s,
                "features": dict(self.features)}


class StepTrace:
    """Append-only measured trace from one source ("serve"/"train")."""

    def __init__(self, source: str = "",
                 meta: Optional[Dict[str, Any]] = None) -> None:
        self.source = source
        self.meta: Dict[str, Any] = dict(meta or {})
        self.events: List[StepEvent] = []

    def record(self, kind: str, duration_s: float,
               **features: float) -> None:
        if kind not in KINDS:
            raise ValueError(f"unknown step kind {kind!r}; "
                             f"pinned kinds: {KINDS}")
        self.events.append(StepEvent(
            kind=kind, duration_s=float(duration_s),
            features={k: float(v) for k, v in features.items()}))

    def durations(self, kinds: Optional[Sequence[str]] = None
                  ) -> List[float]:
        """Durations filtered to ``kinds`` (default: every event)."""
        if kinds is None:
            return [e.duration_s for e in self.events]
        kindset = set(kinds)
        return [e.duration_s for e in self.events if e.kind in kindset]

    def mean_duration_s(self, kinds: Optional[Sequence[str]] = None
                        ) -> float:
        ds = self.durations(kinds)
        return sum(ds) / len(ds) if ds else 0.0

    def feature_values(self, name: str,
                       kinds: Optional[Sequence[str]] = None,
                       default: float = 0.0) -> List[float]:
        """One feature column across events, filtered to ``kinds``.

        Parallel to ``durations(kinds)`` — same events, same order — so
        calibration fits (``fleet.perf.service_model_from_trace``) can
        zip feature columns against measured durations."""
        if kinds is None:
            return [e.features.get(name, default) for e in self.events]
        kindset = set(kinds)
        return [e.features.get(name, default) for e in self.events
                if e.kind in kindset]

    def __len__(self) -> int:
        return len(self.events)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"schema": SCHEMA, "source": self.source,
                "meta": dict(self.meta),
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, doc: Dict[str, Any]) -> "StepTrace":
        if doc.get("schema") != SCHEMA:
            raise ValueError(f"not a steptrace document: "
                             f"schema={doc.get('schema')!r}")
        tr = cls(source=doc.get("source", ""), meta=doc.get("meta"))
        for e in doc.get("events", []):
            tr.events.append(StepEvent(
                kind=e["kind"], duration_s=float(e["duration_s"]),
                features={k: float(v)
                          for k, v in e.get("features", {}).items()}))
        return tr

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    @classmethod
    def read(cls, path: str) -> "StepTrace":
        with open(path) as f:
            return cls.from_dict(json.load(f))
