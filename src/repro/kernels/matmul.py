"""MXU-tiled matmul Pallas kernel (bf16/fp8 inputs, fp32 accumulation).

The paper's MXU story in kernel form: inputs stream HBM->VMEM in
(block_m x block_k) / (block_k x block_n) tiles sized for the 128x128
(bf16) / 256x256+ (Ironwood) systolic arrays — every block dim is a
multiple of 128. Accumulation is fp32 in a VMEM scratch accumulator across
the K grid dimension (grid iterates K innermost), exactly the
multiply-bf16/accumulate-fp32 discipline the paper credits to TPU v2.

Compiled for TPU via Mosaic; validated on CPU with interpret=True against
kernels/ref.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    a: Array,
    b: Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> Array:
    """a: (M, K), b: (K, N) -> (M, N). Block dims must divide the operands
    (pad upstream if needed); all blocks MXU-aligned (multiples of 128 for
    bf16, which also satisfies the fp8 512-lane arrays)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shape ({m},{k})x({k},{n}) not divisible by blocks "
            f"({block_m},{block_k},{block_n})")
    out_dtype = out_dtype or a.dtype
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
