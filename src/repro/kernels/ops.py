"""Jit'd public wrappers for the Pallas kernels.

``impl`` selects: "pallas" (TPU target), "interpret" (CPU validation of the
kernel body), "ref" (pure-jnp oracle). Model code calls these through
ModelContext.attn_impl-style switches; tests sweep impl x shapes x dtypes.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pl
from repro.kernels.decode_attention import \
    paged_decode_attention as _paged_decode_pl
from repro.kernels.decode_attention import \
    paged_decode_span_attention as _paged_span_pl
from repro.kernels.flash_attention import flash_attention as _flash_pl
from repro.kernels.matmul import matmul as _matmul_pl
from repro.kernels.moe_gemm import grouped_matmul as _grouped_pl
from repro.kernels.rwkv_scan import rwkv_wkv as _wkv_pl
from repro.kernels.sparse_gather import sparse_gather_sum as _gather_pl

Array = jax.Array


@partial(jax.jit, static_argnames=("impl", "out_dtype", "block_m",
                                   "block_n", "block_k"))
def matmul(a: Array, b: Array, *, impl: str = "pallas", out_dtype=None,
           block_m: int = 256, block_n: int = 256,
           block_k: int = 512) -> Array:
    if impl == "ref":
        return ref.matmul_ref(a, b, out_dtype)
    return _matmul_pl(a, b, out_dtype=out_dtype, block_m=block_m,
                      block_n=block_n, block_k=block_k,
                      interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "causal", "window",
                                   "block_q", "block_k"))
def flash_attention(q: Array, k: Array, v: Array, *, impl: str = "pallas",
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> Array:
    """(BH, S, D) in/out."""
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window)
    return _flash_pl(q, k, v, causal=causal, window=window,
                     block_q=block_q, block_k=block_k,
                     interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "window", "block_k"))
def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                     *, impl: str = "pallas",
                     window: Optional[int] = None,
                     block_k: int = 512) -> Array:
    if impl == "ref":
        return ref.decode_attention_ref(q, k_cache, v_cache, pos,
                                        window=window)
    return _decode_pl(q, k_cache, v_cache, pos, window=window,
                      block_k=block_k, interpret=impl == "interpret")


# -- paged attention: single-host impls + shard_map mesh wiring -------------
#
# On a serving (data, model) mesh the paged kernels stay PER-SHARD: shard_map
# splits queries on the head axis over "model" (and batch over "data"), each
# shard streaming its local KV-head slice of the page pool through the
# unchanged kernel body. KV placement follows the GQA divisibility story:
#   * kv % model_size == 0  — pool sharded on the KV-head axis (true TP);
#   * otherwise             — pool replicated (the AxisRules fallback) and
#     each shard dynamic-slices the KV groups its local Q heads map to,
#     provided the per-shard head block stays group-aligned;
#   * irregular splits      — heads replicated too (no model partition).
# The host page table and positions are broadcast (or batch-sharded), so
# every shard addresses pages identically and CoW/prefix logic is untouched.


def _paged_decode_local(q, k_pages, v_pages, page_table, pos, k_scale,
                        v_scale, impl, window):
    if impl == "ref":
        kg = ref.paged_gather_dequant_ref(k_pages, page_table, k_scale,
                                          q.dtype)
        vg = ref.paged_gather_dequant_ref(v_pages, page_table, v_scale,
                                          q.dtype)
        return ref.decode_attention_ref(q, kg, vg, pos, window=window)
    return _paged_decode_pl(q, k_pages, v_pages, page_table, pos,
                            k_scale=k_scale, v_scale=v_scale,
                            window=window, interpret=impl == "interpret")


def _paged_span_local(q, k_pages, v_pages, page_table, pos, k_scale,
                      v_scale, impl, window):
    if impl == "ref":
        kg = ref.paged_gather_dequant_ref(k_pages, page_table, k_scale,
                                          q.dtype)
        vg = ref.paged_gather_dequant_ref(v_pages, page_table, v_scale,
                                          q.dtype)
        return ref.decode_span_attention_ref(q, kg, vg, pos, window=window)
    return _paged_span_pl(q, k_pages, v_pages, page_table, pos,
                          k_scale=k_scale, v_scale=v_scale,
                          window=window, interpret=impl == "interpret")


def _mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _paged_partition(mesh, data_axis, model_axis, b, h, kv):
    """Static plan for splitting paged attention over a (data, model) mesh.

    Returns (data_spec_axis, head_spec_axis, kv_spec_axis, slice_kv,
    model_size). ``slice_kv`` marks the replicated-KV GQA fallback where
    each shard dynamic-slices its local KV groups out of the full pool."""
    d = _mesh_axis_size(mesh, data_axis)
    m = _mesh_axis_size(mesh, model_axis)
    db = data_axis if (d > 1 and b % d == 0) else None
    hm = model_axis if (m > 1 and h % m == 0) else None
    kvm = None
    slice_kv = False
    if hm is not None:
        if kv % m == 0:
            kvm = model_axis  # KV pool shards with the Q heads (true TP)
        else:
            h_local, g = h // m, h // kv
            if h_local % g == 0 or g % h_local == 0:
                slice_kv = True  # replicated pool, group-aligned local view
            else:
                hm = None  # irregular group split: replicate heads too
    return db, hm, kvm, slice_kv, m


def _local_kv_slice(arrs, model_axis, h, kv, m):
    """Inside shard_map with replicated pools: slice the KV-head groups
    that shard ``axis_index(model_axis)``'s local Q heads map to. Local
    head j then sees local KV head j // (h_local // kv_local), matching
    the global GQA grouping because the head block is group-aligned."""
    idx = jax.lax.axis_index(model_axis)
    h_local, g = h // m, h // kv
    kv_local = max(1, h_local // g)
    start = (idx * h_local) // g
    return [None if a is None else
            jax.lax.dynamic_slice_in_dim(a, start, kv_local, axis=2)
            for a in arrs]


def _paged_sharded(local_fn, mesh, data_axis, model_axis, head_axis, q,
                   k_pages, v_pages, page_table, pos, k_scale, v_scale):
    b, h, kv = q.shape[0], q.shape[head_axis], k_pages.shape[2]
    db, hm, kvm, slice_kv, m = _paged_partition(
        mesh, data_axis, model_axis, b, h, kv)
    if db is None and hm is None:
        return local_fn(q, k_pages, v_pages, page_table, pos, k_scale,
                        v_scale)
    qaxes = [db] + [None] * (q.ndim - 1)
    qaxes[head_axis] = hm
    qspec = P(*qaxes)
    pspec, sspec = P(None, None, kvm, None), P(None, None, kvm)
    operands = [q, k_pages, v_pages, page_table, pos]
    specs = [qspec, pspec, pspec, P(db, None), P(db)]
    has_scale = k_scale is not None
    if has_scale:
        operands += [k_scale, v_scale]
        specs += [sspec, sspec]

    def body(*xs):
        ql, kp, vp, tab, posl = xs[:5]
        ks, vs = (xs[5], xs[6]) if has_scale else (None, None)
        if slice_kv:
            kp, vp, ks, vs = _local_kv_slice([kp, vp, ks, vs],
                                             model_axis, h, kv, m)
        return local_fn(ql, kp, vp, tab, posl, ks, vs)

    return shard_map(body, mesh=mesh, in_specs=tuple(specs),
                     out_specs=qspec, check_rep=False)(*operands)


@partial(jax.jit, static_argnames=("impl", "window", "mesh", "data_axis",
                                   "model_axis"))
def paged_decode_attention(q: Array, k_pages: Array, v_pages: Array,
                           page_table: Array, pos: Array, *,
                           k_scale: Optional[Array] = None,
                           v_scale: Optional[Array] = None,
                           impl: str = "pallas",
                           window: Optional[int] = None,
                           mesh=None, data_axis: str = "data",
                           model_axis: str = "model") -> Array:
    """q: (B,H,D); pages (N,P,KV,D); page_table (B,M); pos (B,).

    int8 pages stream natively when the (N,P,KV) ``k_scale``/``v_scale``
    pools are passed: the kernel dequantizes in VMEM, page by page.
    "ref" gathers (and dequantizes) the pages and reuses the dense ring
    oracle (no wraps: every absolute position is < M*P by construction).
    ``mesh``: when set, shard_map the call over (data_axis, model_axis) —
    heads split over "model", batch over "data", KV pool sharded or
    replicate-and-sliced per the GQA plan above."""
    local = partial(_paged_decode_local, impl=impl, window=window)
    if mesh is not None:
        return _paged_sharded(local, mesh, data_axis, model_axis, 1, q,
                              k_pages, v_pages, page_table, pos, k_scale,
                              v_scale)
    return local(q, k_pages, v_pages, page_table, pos, k_scale, v_scale)


@partial(jax.jit, static_argnames=("impl", "window", "mesh", "data_axis",
                                   "model_axis"))
def paged_decode_span_attention(q: Array, k_pages: Array, v_pages: Array,
                                page_table: Array, pos: Array, *,
                                k_scale: Optional[Array] = None,
                                v_scale: Optional[Array] = None,
                                impl: str = "pallas",
                                window: Optional[int] = None,
                                mesh=None, data_axis: str = "data",
                                model_axis: str = "model") -> Array:
    """k-token-query paged decode. q: (B,T,H,D) — T consecutive tokens
    per sequence at absolute positions ``pos .. pos+T-1`` (speculative
    verify / suffix prefill / chunked cold prefill); pages (N,P,KV,D);
    page_table (B,M); pos (B,) valid count BEFORE the span. int8 pages
    stream natively via ``k_scale``/``v_scale``. ``mesh`` shard_maps the
    call exactly like paged_decode_attention (head axis 2 here).
    Returns (B,T,H,D)."""
    local = partial(_paged_span_local, impl=impl, window=window)
    if mesh is not None:
        return _paged_sharded(local, mesh, data_axis, model_axis, 2, q,
                              k_pages, v_pages, page_table, pos, k_scale,
                              v_scale)
    return local(q, k_pages, v_pages, page_table, pos, k_scale, v_scale)


# -- grouped MoE GEMM: single-host impl + shard_map expert parallelism -----


def _grouped_local(x, w, group_ids, w_scale, impl, block_f):
    if impl == "ref":
        return ref.grouped_matmul_ref(x, w, group_ids, w_scale=w_scale)
    return _grouped_pl(x, w, group_ids, w_scale=w_scale, block_f=block_f,
                       interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "block_f", "mesh",
                                   "expert_axis"))
def grouped_matmul(x: Array, w: Array, group_ids: Array, *,
                   w_scale: Optional[Array] = None,
                   impl: str = "pallas", block_f: int = 512,
                   mesh=None, expert_axis: str = "data") -> Array:
    """m-grouped contiguous GEMM over expert-sorted token rows.

    x: (M, D) sorted+padded rows; w: (E, D, F); group_ids (M // block_m,)
    expert id per m-tile (-1 = pad tile -> zero rows). ``w_scale`` (E,)
    dequantizes int8 expert weights inside the kernel.

    ``mesh``: when set, shard_map the call with experts sharded over
    ``expert_axis`` (the "data" mesh axis, matching AxisRules' "expert"
    placement): each shard keeps its contiguous E/ep slice of ``w``,
    rewrites global tile ids into its local range (-1 elsewhere, so
    non-local tiles produce zeros), and a psum restores the full (M, F)
    output — every tile is owned by exactly one shard. Experts that
    don't divide the axis fall back to replicated weights (the same
    divisibility story as the paged-attention GQA fallback)."""
    e = w.shape[0]
    local = partial(_grouped_local, impl=impl, block_f=block_f)
    ep = _mesh_axis_size(mesh, expert_axis) if mesh is not None else 1
    if ep <= 1 or e % ep:
        return local(x, w, group_ids, w_scale)
    e_local = e // ep
    has_scale = w_scale is not None

    def body(xl, wl, gids, *rest):
        sl = rest[0] if has_scale else None
        lo = jax.lax.axis_index(expert_axis) * e_local
        g = gids - lo
        g = jnp.where((g >= 0) & (g < e_local), g, -1)
        out = local(xl, wl, g, sl)
        return jax.lax.psum(out, expert_axis)

    operands = [x, w, group_ids]
    specs = [P(None, None), P(expert_axis, None, None), P(None)]
    if has_scale:
        operands.append(w_scale)
        specs.append(P(expert_axis))
    return shard_map(body, mesh=mesh, in_specs=tuple(specs),
                     out_specs=P(None, None), check_rep=False)(*operands)


@partial(jax.jit, static_argnames=("impl", "chunk"))
def rwkv_wkv(r: Array, k: Array, v: Array, logw: Array, u: Array, *,
             impl: str = "pallas", chunk: int = 16) -> Array:
    if impl == "ref":
        return ref.rwkv_wkv_ref(r, k, v, logw, u)
    return _wkv_pl(r, k, v, logw, u, chunk=chunk,
                   interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl",))
def sparse_gather_sum(table: Array, indices: Array, weights: Array, *,
                      impl: str = "pallas") -> Array:
    if impl == "ref":
        return ref.sparse_gather_sum_ref(table, indices, weights)
    return _gather_pl(table, indices, weights,
                      interpret=impl == "interpret")
