"""Jit'd public wrappers for the Pallas kernels.

``impl`` selects: "pallas" (TPU target), "interpret" (CPU validation of the
kernel body), "ref" (pure-jnp oracle). Model code calls these through
ModelContext.attn_impl-style switches; tests sweep impl x shapes x dtypes.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pl
from repro.kernels.decode_attention import \
    paged_decode_attention as _paged_decode_pl
from repro.kernels.decode_attention import \
    paged_decode_span_attention as _paged_span_pl
from repro.kernels.flash_attention import flash_attention as _flash_pl
from repro.kernels.matmul import matmul as _matmul_pl
from repro.kernels.rwkv_scan import rwkv_wkv as _wkv_pl
from repro.kernels.sparse_gather import sparse_gather_sum as _gather_pl

Array = jax.Array


@partial(jax.jit, static_argnames=("impl", "out_dtype", "block_m",
                                   "block_n", "block_k"))
def matmul(a: Array, b: Array, *, impl: str = "pallas", out_dtype=None,
           block_m: int = 256, block_n: int = 256,
           block_k: int = 512) -> Array:
    if impl == "ref":
        return ref.matmul_ref(a, b, out_dtype)
    return _matmul_pl(a, b, out_dtype=out_dtype, block_m=block_m,
                      block_n=block_n, block_k=block_k,
                      interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "causal", "window",
                                   "block_q", "block_k"))
def flash_attention(q: Array, k: Array, v: Array, *, impl: str = "pallas",
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128) -> Array:
    """(BH, S, D) in/out."""
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window)
    return _flash_pl(q, k, v, causal=causal, window=window,
                     block_q=block_q, block_k=block_k,
                     interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "window", "block_k"))
def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                     *, impl: str = "pallas",
                     window: Optional[int] = None,
                     block_k: int = 512) -> Array:
    if impl == "ref":
        return ref.decode_attention_ref(q, k_cache, v_cache, pos,
                                        window=window)
    return _decode_pl(q, k_cache, v_cache, pos, window=window,
                      block_k=block_k, interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "window"))
def paged_decode_attention(q: Array, k_pages: Array, v_pages: Array,
                           page_table: Array, pos: Array, *,
                           k_scale: Optional[Array] = None,
                           v_scale: Optional[Array] = None,
                           impl: str = "pallas",
                           window: Optional[int] = None) -> Array:
    """q: (B,H,D); pages (N,P,KV,D); page_table (B,M); pos (B,).

    int8 pages stream natively when the (N,P,KV) ``k_scale``/``v_scale``
    pools are passed: the kernel dequantizes in VMEM, page by page.
    "ref" gathers (and dequantizes) the pages and reuses the dense ring
    oracle (no wraps: every absolute position is < M*P by
    construction)."""
    if impl == "ref":
        kg = ref.paged_gather_dequant_ref(k_pages, page_table, k_scale,
                                          q.dtype)
        vg = ref.paged_gather_dequant_ref(v_pages, page_table, v_scale,
                                          q.dtype)
        return ref.decode_attention_ref(q, kg, vg, pos, window=window)
    return _paged_decode_pl(q, k_pages, v_pages, page_table, pos,
                            k_scale=k_scale, v_scale=v_scale,
                            window=window, interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "window"))
def paged_decode_span_attention(q: Array, k_pages: Array, v_pages: Array,
                                page_table: Array, pos: Array, *,
                                k_scale: Optional[Array] = None,
                                v_scale: Optional[Array] = None,
                                impl: str = "pallas",
                                window: Optional[int] = None) -> Array:
    """k-token-query paged decode. q: (B,T,H,D) — T consecutive tokens
    per sequence at absolute positions ``pos .. pos+T-1`` (speculative
    verify / suffix prefill / chunked cold prefill); pages (N,P,KV,D);
    page_table (B,M); pos (B,) valid count BEFORE the span. int8 pages
    stream natively via ``k_scale``/``v_scale``. Returns (B,T,H,D)."""
    if impl == "ref":
        kg = ref.paged_gather_dequant_ref(k_pages, page_table, k_scale,
                                          q.dtype)
        vg = ref.paged_gather_dequant_ref(v_pages, page_table, v_scale,
                                          q.dtype)
        return ref.decode_span_attention_ref(q, kg, vg, pos, window=window)
    return _paged_span_pl(q, k_pages, v_pages, page_table, pos,
                          k_scale=k_scale, v_scale=v_scale,
                          window=window, interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl", "chunk"))
def rwkv_wkv(r: Array, k: Array, v: Array, logw: Array, u: Array, *,
             impl: str = "pallas", chunk: int = 16) -> Array:
    if impl == "ref":
        return ref.rwkv_wkv_ref(r, k, v, logw, u)
    return _wkv_pl(r, k, v, logw, u, chunk=chunk,
                   interpret=impl == "interpret")


@partial(jax.jit, static_argnames=("impl",))
def sparse_gather_sum(table: Array, indices: Array, weights: Array, *,
                      impl: str = "pallas") -> Array:
    if impl == "ref":
        return ref.sparse_gather_sum_ref(table, indices, weights)
    return _gather_pl(table, indices, weights,
                      interpret=impl == "interpret")
