"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def matmul_ref(a: Array, b: Array, out_dtype=None) -> Array:
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    return out.astype(out_dtype or a.dtype)


def flash_attention_ref(q: Array, k: Array, v: Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> Array:
    """q,k,v: (BH, S, D)."""
    bh, s, d = q.shape
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    allowed = jnp.ones((s, s), bool)
    if causal:
        allowed &= qpos >= kpos
    if window is not None:
        allowed &= (qpos - kpos) < window
    scores = jnp.where(allowed, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: Array, k_cache: Array, v_cache: Array,
                         pos: Array, *,
                         window: Optional[int] = None) -> Array:
    """q: (B,H,D); caches (B,W,KV,D); pos: (B,). Ring-buffer aware."""
    b, h, d = q.shape
    _, w, kv, _ = k_cache.shape
    groups = h // kv
    slot = jnp.arange(w)
    wraps = jnp.maximum(pos[:, None] - 1 - slot[None, :], 0) // w
    abs_pos = slot[None, :] + wraps * w
    valid = abs_pos < pos[:, None]
    if window is not None:
        valid &= abs_pos >= pos[:, None] - window
    kf = jnp.repeat(k_cache, groups, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_cache, groups, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf)
    scores = scores * (d ** -0.5)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, vf)
    return out.astype(q.dtype)


def decode_span_attention_ref(q: Array, k_cache: Array, v_cache: Array,
                              pos: Array, *,
                              window: Optional[int] = None) -> Array:
    """T-query decode oracle against an append-only (non-ring) cache.

    q: (B,T,H,D); caches (B,S,KV,D) at absolute slots; pos: (B,) valid
    token count BEFORE the span — query t sits at position pos + t and
    sees slots <= its own position. Returns (B,T,H,D)."""
    b, t, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    groups = h // kv
    qpos = pos[:, None] + jnp.arange(t)[None, :]  # (B, T)
    spos = jnp.arange(s)[None, None, :]
    valid = spos <= qpos[..., None]  # (B, T, S)
    if window is not None:
        valid &= spos > qpos[..., None] - window
    kf = jnp.repeat(k_cache, groups, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_cache, groups, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32), kf)
    scores = scores * (d ** -0.5)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, vf)
    return out.astype(q.dtype)


def paged_gather_dequant_ref(pages: Array, page_table: Array,
                             scale: Optional[Array], dtype) -> Array:
    """Materialize each request's contiguous KV view from the page pool:
    (N, P, KV, D) pages + (B, M) table -> (B, M*P, KV, D) in ``dtype``.
    ``scale`` (N, P, KV) dequantizes int8 pages. This is the gather
    oracle the in-kernel page stream is checked against — the kernel
    never materializes this array."""
    n, p, kv, d = pages.shape
    b, m = page_table.shape
    g = pages[page_table]  # (B, M, P, KV, D)
    if scale is not None:
        g = g.astype(jnp.float32) * scale[page_table][..., None]
    return g.astype(dtype).reshape(b, m * p, kv, d)


def grouped_matmul_ref(x: Array, w: Array, group_ids: Array, *,
                       w_scale: Optional[Array] = None,
                       out_dtype=None) -> Array:
    """Segment-matmul oracle for the m-grouped contiguous MoE GEMM.

    x: (M, D) sorted+padded token rows; w: (E, D, F); group_ids:
    (M // block_m,) expert id per m-tile (-1 = pad-only tile -> zeros).
    ``w_scale`` (E,) fp32 is applied to the fp32 product after the dot,
    matching the kernel's post-accumulation dequant exactly."""
    m, d = x.shape
    nb = group_ids.shape[0]
    bm = m // nb
    xb = x.reshape(nb, bm, d).astype(jnp.float32)
    gmax = jnp.maximum(group_ids, 0)
    wb = w[gmax].astype(jnp.float32)  # (nb, D, F)
    out = jnp.einsum("bmd,bdf->bmf", xb, wb)
    if w_scale is not None:
        out = out * w_scale.astype(jnp.float32)[gmax][:, None, None]
    out = jnp.where(group_ids[:, None, None] >= 0, out, 0.0)
    return out.reshape(m, -1).astype(out_dtype or x.dtype)


def rwkv_wkv_ref(r: Array, k: Array, v: Array, logw: Array,
                 u: Array) -> Array:
    """Token-serial recurrence (the definitional oracle).
    r,k,v,logw: (BH,S,hd) fp32; u: (BH,hd)."""
    bh, s, hd = r.shape

    def per_seq(r1, k1, v1, lw1, u1):
        def step(state, xs):
            rt, kt, vt, lwt = xs
            kv = jnp.outer(kt, vt)  # (hd_k, hd_v)
            out = rt @ (state + u1[:, None] * kv)
            new_state = jnp.exp(lwt)[:, None] * state + kv
            return new_state, out

        s0 = jnp.zeros((hd, hd), jnp.float32)
        _, outs = jax.lax.scan(step, s0, (r1, k1, v1, lw1))
        return outs

    return jax.vmap(per_seq)(r, k, v, logw, u)


def sparse_gather_sum_ref(table: Array, indices: Array,
                          weights: Array) -> Array:
    rows = table[indices]  # (N, bag, D)
    out = jnp.einsum("nbd,nb->nd", rows.astype(jnp.float32),
                     weights.astype(jnp.float32))
    return out.astype(table.dtype)
