"""m-grouped contiguous GEMM Pallas kernel for sort-based dropless MoE.

The dropless dispatch sorts token rows by routed expert and pads each
expert's group to a ``block_m`` boundary, so every m-tile of the sorted
buffer belongs to exactly ONE expert. The per-tile expert id array is
scalar-prefetched (the same BlockSpec discipline as decode_attention's
page table): the weight BlockSpec's index_map reads ``group_ids[i]`` at
DMA time and pulls that expert's (D, block_f) weight tile into VMEM —
no (E, T, D) capacity buffer ever exists.

Tiles whose id is the sentinel ``-1`` (pad-only, or non-local under
expert parallelism) write zeros; the combine step never reads pad rows,
and zeros are the psum identity for the EP wrapper in kernels/ops.py.

int8 expert weights stream natively: pass per-expert scalar ``w_scale``
(E,) and the kernel applies it to the fp32 accumulator after the dot
(exact for a scalar scale: ``s * dot(x, w) == dot(x, s * w)``).

Compiled for TPU via Mosaic; validated on CPU with interpret=True
against kernels/ref.grouped_matmul_ref.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _grouped_kernel(gid_ref, x_ref, w_ref, o_ref):
    i = pl.program_id(0)
    g = gid_ref[i]
    acc = jnp.dot(x_ref[...], w_ref[0],
                  preferred_element_type=jnp.float32)
    # Pad-only / non-local tile: the weight DMA fetched expert 0's tile
    # (index_map clamps the sentinel); discard it and write zeros.
    o_ref[...] = jnp.where(g >= 0, acc, 0.0).astype(o_ref.dtype)


def _grouped_kernel_scaled(gid_ref, scale_ref, x_ref, w_ref, o_ref):
    i = pl.program_id(0)
    g = gid_ref[i]
    acc = jnp.dot(x_ref[...].astype(jnp.float32),
                  w_ref[0].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc = acc * scale_ref[jnp.maximum(g, 0)]
    o_ref[...] = jnp.where(g >= 0, acc, 0.0).astype(o_ref.dtype)


def grouped_matmul(x: Array, w: Array, group_ids: Array, *,
                   w_scale: Optional[Array] = None,
                   block_f: int = 512,
                   out_dtype=None,
                   interpret: bool = False) -> Array:
    """x: (M, D) sorted+padded token rows; w: (E, D, F) expert weights;
    group_ids: (M // block_m,) int32 expert id per m-tile (-1 sentinel
    for pad-only tiles). block_m is implied by M // len(group_ids).
    ``w_scale`` (E,) fp32 dequantizes int8 ``w`` per expert. -> (M, F).
    """
    m, d = x.shape
    e, d2, f = w.shape
    if d != d2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    nb = group_ids.shape[0]
    if nb == 0 or m % nb:
        raise ValueError(f"M={m} not divisible into {nb} m-tiles")
    block_m = m // nb
    block_f = min(block_f, f)
    if f % block_f:
        raise ValueError(f"F={f} not divisible by block_f={block_f}")
    group_ids = group_ids.astype(jnp.int32)
    out_dtype = out_dtype or x.dtype

    if w_scale is None:
        kernel = _grouped_kernel
        nsp = 1
        operands = (group_ids, x, w)
    else:
        kernel = _grouped_kernel_scaled
        nsp = 2
        operands = (group_ids, w_scale.astype(jnp.float32), x, w)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsp,
        grid=(nb, f // block_f),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j, *refs: (i, 0)),
            # The scalar-prefetched tile->expert table drives the weight
            # gather at DMA time (clamp the -1 sentinel to a valid row).
            pl.BlockSpec(
                (1, d, block_f),
                lambda i, j, gid_ref, *refs:
                    (jnp.maximum(gid_ref[i], 0), 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_f),
                               lambda i, j, *refs: (i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, f), out_dtype),
        interpret=interpret,
    )(*operands)
