"""RWKV-6 chunked WKV Pallas kernel (data-dependent-decay linear attention).

TPU adaptation of the recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t,
out_t = r_t (S_{t-1} + diag(u) k_t^T v_t): instead of a token-serial loop
(VPU-bound, no MXU work), the sequence is processed in chunks whose
intra-chunk interactions are (chunk x chunk) MXU matmuls with bounded
exponents (per-step log-decay clamped, matching models/rwkv6.DECAY_CLAMP),
while the (hd x hd) state matrix lives in VMEM scratch across the
sequential chunk grid dimension. One grid step = one chunk: stream
r/k/v/decay chunks HBM->VMEM, two small matmuls + state update, emit the
chunk's outputs. Layout (B*H, S, hd); fp32 throughout (the state is a
running sum — range matters, the paper's BF16 lesson in reverse).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0]  # (c, hd) fp32
    k = k_ref[0]
    v = v_ref[0]
    lw = lw_ref[0]  # per-step log decay, <= 0, clamped
    u = u_ref[0]  # (1, hd) bonus

    cum = jnp.cumsum(lw, axis=0)  # (c, hd) within-chunk cumulative
    total = cum[-1]  # (hd,)
    cum_excl = cum - lw

    # inter-chunk: r_t reads state decayed from chunk start to t-1
    r_in = r * jnp.exp(cum_excl)
    inter = jax.lax.dot_general(
        r_in, s_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (c, hd_v)

    # intra-chunk: scores[t,s] = sum_d r_t k_s exp(cum_excl[t]-cum[s]), s<t
    k_neg = k * jnp.exp(-cum)  # bounded by exp(chunk*|clamp|)
    scores = jax.lax.dot_general(
        r_in, k_neg, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (c, c)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(t_idx > s_idx, scores, 0.0)
    intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # current-token bonus: (r_t . (u*k_t)) v_t
    bonus = jnp.sum(r * k * u, axis=-1, keepdims=True)  # (c, 1)
    o_ref[0, ...] = inter + intra + bonus * v

    # state update: S' = diag(exp(total)) S + sum_s (k_s exp(total-cum_s))^T v_s
    k_out = k * jnp.exp(total[None, :] - cum)
    delta = jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (hd_k, hd_v)
    s_ref[...] = s_ref[...] * jnp.exp(total)[:, None] + delta


def rwkv_wkv(
    r: Array, k: Array, v: Array, logw: Array, u: Array, *,
    chunk: int = 16,
    interpret: bool = False,
) -> Array:
    """r,k,v,logw: (BH, S, hd) fp32; u: (BH, hd). Returns (BH, S, hd).

    logw must already be clamped to >= DECAY_CLAMP (the wrapper does it)."""
    bh, s, hd = r.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk}")
    grid = (bh, s // chunk)
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, hd), lambda h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
