"""SparseCore-style embedding gather/bag Pallas kernel.

The paper's SparseCore tiles "read activations and parameters from HBM into
the tile's slice of Sparse Vector Memory" with data-dependent addresses —
embedding-bag lookups. TPU adaptation: the index array is *scalar-
prefetched* (PrefetchScalarGridSpec) so the BlockSpec index_map can steer
each grid step's HBM->VMEM DMA to the right embedding row — the gather
never materializes an index tensor on the vector units, matching the
Fetch-Unit design.

Each grid step processes one bag: ``bag_size`` rows are DMA'd (one block
per row via the index map), summed with weights in VMEM, one output row
written back (the Flush-Unit direction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _gather_kernel(idx_ref, table_ref, w_ref, o_ref, acc_ref, *,
                   bag_size: int):
    j = pl.program_id(1)  # position within the bag

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    weight = w_ref[0, j]
    acc_ref[...] += table_ref[...].astype(jnp.float32) * weight

    @pl.when(j == bag_size - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def sparse_gather_sum(
    table: Array, indices: Array, weights: Array, *,
    interpret: bool = False,
) -> Array:
    """Embedding bag: out[i] = sum_j weights[i,j] * table[indices[i,j]].

    table: (V, D); indices: (N, bag) int32; weights: (N, bag) fp32.
    Returns (N, D)."""
    v, d = table.shape
    n, bag = indices.shape
    grid = (n, bag)
    return pl.pallas_call(
        functools.partial(_gather_kernel, bag_size=bag),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # DMA exactly the row the prefetched index names
                pl.BlockSpec((1, d), lambda i, j, idx: (idx[i, j], 0)),
                pl.BlockSpec((1, bag), lambda i, j, idx: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, j, idx: (i, 0)),
            scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(indices, table, weights)
