"""Decode attention Pallas kernel: one query token vs a long KV cache.

Memory-bound by design (the roofline term that dominates decode cells):
the kernel's job is to stream the KV cache through VMEM exactly once at
full HBM bandwidth while the (tiny) query stays resident. Blockwise over
the cache length with an online-softmax running state, GQA-aware: the
query block carries all heads of one sequence; each KV head is used by
n_heads/n_kv query heads via in-VMEM reshape (no HBM duplication —
SparseCore-style "read once, use many").

Validity masking supports ring buffers: slot i holds absolute position
i + W*wraps (see models/attention.decode_attention, the jnp oracle).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   block_k: int, n_k: int, window: Optional[int],
                   cache_len: int, scale: float, groups: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-request valid token count (prefetched); continuous batching
    # serves different sequence lengths in one lockstep batch
    pos = pos_ref[pl.program_id(0)]
    q = q_ref[0].astype(jnp.float32) * scale  # (H, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, KV, d)
    bk, kv, d = k.shape
    h = q.shape[0]
    # GQA: fold q heads into (KV, groups) so scores come from one batched dot
    qg = q.reshape(kv, groups, d)
    scores = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)  # (KV, groups, bk)

    slot = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
    wraps = jnp.maximum(pos - 1 - slot, 0) // cache_len
    abs_pos = slot + wraps * cache_len
    valid = abs_pos < pos
    if window is not None:
        valid &= abs_pos >= pos - window
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]  # (KV, groups)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    p = jnp.exp(scores - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    v_f = v_ref[0].astype(jnp.float32)  # (bk, KV, d)
    pv = jax.lax.dot_general(
        p, v_f, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)  # (KV, groups, d)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        out = (acc_ref[...] / denom).reshape(h, d)
        o_ref[0, ...] = out.astype(o_ref.dtype)


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, pos: Array, *,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> Array:
    """q: (B, H, D); caches: (B, W, KV, D); pos: (B,) int32 (uniform).
    Returns (B, H, D)."""
    b, h, d = q.shape
    _, w, kv, _ = k_cache.shape
    groups = h // kv
    block_k = min(block_k, w)
    if w % block_k:
        raise ValueError(f"cache window {w} % block {block_k}")
    n_k = w // block_k
    grid = (b, n_k)
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(
            _decode_kernel, block_k=block_k, n_k=n_k, window=window,
            cache_len=w, scale=scale, groups=groups),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, h, d), lambda i, j, pos_ref: (i, 0, 0)),
                pl.BlockSpec((1, block_k, kv, d),
                             lambda i, j, pos_ref: (i, j, 0, 0)),
                pl.BlockSpec((1, block_k, kv, d),
                             lambda i, j, pos_ref: (i, j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, h, d),
                                   lambda i, j, pos_ref: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, groups), jnp.float32),
                pltpu.VMEM((kv, groups), jnp.float32),
                pltpu.VMEM((kv, groups, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(pos, q, k_cache, v_cache)


# ---------------------------------------------------------------------------
# Paged variant: KV lives in a shared (N, P, KV, D) page pool; each grid
# step DMAs one *page* selected through the scalar-prefetched page table —
# the BlockSpec index_map reads ``table[b, j]``, so the gather happens at
# DMA-issue time with no HBM materialization of a contiguous cache
# (vLLM-style paged attention as a Pallas grid). int8 pools are
# quantization-native: the page-aligned (N, P, KV) scale pages ride the
# same table entry as their KV page and dequantize in VMEM, so a
# quantized cache streams half the HBM bytes per token instead of paying
# a gather-dequant materialization (the Ironwood int8-KV memory lever).
# ---------------------------------------------------------------------------


def _paged_decode_kernel(pos_ref, table_ref, q_ref, k_ref, v_ref, *rest,
                         page_size: int, n_pages: int,
                         window: Optional[int], scale: float, groups: int,
                         quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    ib, ij = pl.program_id(0), pl.program_id(1)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    q = q_ref[0].astype(jnp.float32) * scale  # (H, d)
    k = k_ref[0].astype(jnp.float32)          # (P, KV, d)
    if quantized:
        # in-VMEM dequant: int8 page bytes streamed from HBM, scale page
        # (P, KV) DMA'd through the same table entry
        k = k * ks_ref[0].astype(jnp.float32)[..., None]
    p, kv, d = k.shape
    h = q.shape[0]
    qg = q.reshape(kv, groups, d)
    scores = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)  # (KV, groups, P)

    # pages are append-only (no ring): absolute position == global slot
    abs_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, p), 2)
    valid = abs_pos < pos
    if window is not None:
        valid &= abs_pos >= pos - window
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    pr = jnp.exp(scores - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + pr.sum(axis=-1)
    v_f = v_ref[0].astype(jnp.float32)
    if quantized:
        v_f = v_f * vs_ref[0].astype(jnp.float32)[..., None]
    pv = jax.lax.dot_general(
        pr, v_f, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(ij == n_pages - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, ...] = (acc_ref[...] / denom).reshape(h, d).astype(
            o_ref.dtype)


def _paged_span_kernel(pos_ref, table_ref, q_ref, k_ref, v_ref, *rest,
                       page_size: int, n_pages: int,
                       window: Optional[int], scale: float, groups: int,
                       quantized: bool):
    """k-token-query variant of ``_paged_decode_kernel``.

    The query block carries ``span`` consecutive tokens of one sequence
    (speculative draft-verify, a suffix prefill behind a cached prefix,
    or one chunk of a cold chunked prefill). Query t sits at absolute
    position ``pos + t`` and is masked causally against the streamed
    pages — the online-softmax state gains a span axis, everything else
    is the one-pass page stream."""
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    ib, ij = pl.program_id(0), pl.program_id(1)

    @pl.when(ij == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[ib]
    q = q_ref[0].astype(jnp.float32) * scale  # (T, H, d)
    k = k_ref[0].astype(jnp.float32)          # (P, KV, d)
    if quantized:
        k = k * ks_ref[0].astype(jnp.float32)[..., None]
    p, kv, d = k.shape
    t, h = q.shape[0], q.shape[1]
    qg = q.reshape(t, kv, groups, d)
    # batch over KV heads, contract d: (KV, T, groups, P) in one dot
    scores = jax.lax.dot_general(
        qg, k, (((3,), (2,)), ((1,), (1,))),
        preferred_element_type=jnp.float32)

    # pages are append-only: absolute position == global slot. Query t
    # (position pos + t) sees positions <= its own.
    t_iota = jax.lax.broadcasted_iota(jnp.int32, (1, t, 1, p), 1)
    abs_pos = ij * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, t, 1, p), 3)
    qpos = pos + t_iota
    valid = abs_pos <= qpos
    if window is not None:
        valid &= abs_pos > qpos - window
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]  # (KV, T, groups)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    pr = jnp.exp(scores - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + pr.sum(axis=-1)
    v_f = v_ref[0].astype(jnp.float32)  # (P, KV, d)
    if quantized:
        v_f = v_f * vs_ref[0].astype(jnp.float32)[..., None]
    pv = jax.lax.dot_general(
        pr, v_f, (((3,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)  # (KV, T, groups, d)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(ij == n_pages - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        out = jnp.swapaxes(acc_ref[...] / denom, 0, 1)  # (T, KV, groups, d)
        o_ref[0, ...] = out.reshape(t, h, d).astype(o_ref.dtype)


def _scale_specs(quantized: bool, p: int, kv: int):
    """BlockSpecs for the (N, P, KV) scale pages: one (1, P, KV) scale
    block rides the same scalar-prefetched table entry as its KV page."""
    if not quantized:
        return []
    return [pl.BlockSpec((1, p, kv),
                         lambda i, j, pos_ref, tab_ref:
                         (tab_ref[i, j], 0, 0))] * 2


def paged_decode_span_attention(
    q: Array, k_pages: Array, v_pages: Array, page_table: Array,
    pos: Array, *,
    k_scale: Optional[Array] = None,
    v_scale: Optional[Array] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> Array:
    """q: (B, T, H, D) — T consecutive query tokens per sequence at
    absolute positions ``pos .. pos + T - 1`` (the span's own k/v must
    already be written to the pages). Other args as
    ``paged_decode_attention``. Returns (B, T, H, D)."""
    b, t, h, d = q.shape
    n, p, kv, _ = k_pages.shape
    m = page_table.shape[1]
    groups = h // kv
    grid = (b, m)
    scale = d ** -0.5
    quantized = k_scale is not None
    operands = (q, k_pages, v_pages) + (
        (k_scale, v_scale) if quantized else ())
    return pl.pallas_call(
        functools.partial(
            _paged_span_kernel, page_size=p, n_pages=m,
            window=window, scale=scale, groups=groups,
            quantized=quantized),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, t, h, d),
                             lambda i, j, pos_ref, tab_ref: (i, 0, 0, 0)),
                pl.BlockSpec((1, p, kv, d),
                             lambda i, j, pos_ref, tab_ref:
                             (tab_ref[i, j], 0, 0, 0)),
                pl.BlockSpec((1, p, kv, d),
                             lambda i, j, pos_ref, tab_ref:
                             (tab_ref[i, j], 0, 0, 0)),
                *_scale_specs(quantized, p, kv),
            ],
            out_specs=pl.BlockSpec((1, t, h, d),
                                   lambda i, j, pos_ref, tab_ref:
                                   (i, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, t, groups), jnp.float32),
                pltpu.VMEM((kv, t, groups), jnp.float32),
                pltpu.VMEM((kv, t, groups, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        interpret=interpret,
    )(pos, page_table, *operands)


def paged_decode_attention(
    q: Array, k_pages: Array, v_pages: Array, page_table: Array,
    pos: Array, *,
    k_scale: Optional[Array] = None,
    v_scale: Optional[Array] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> Array:
    """q: (B, H, D); pages: (N, P, KV, D); page_table: (B, M) int32 page
    ids (unused entries point at the reserved trash page 0); pos: (B,)
    per-request valid token count. Returns (B, H, D).

    int8 pages stream natively: pass the page-aligned ``k_scale`` /
    ``v_scale`` pools (N, P, KV) and the kernel DMAs each scale page
    through the same table entry as its KV page, dequantizing in VMEM —
    half the HBM bytes per token, no gather materialization.
    """
    b, h, d = q.shape
    n, p, kv, _ = k_pages.shape
    m = page_table.shape[1]
    groups = h // kv
    grid = (b, m)
    scale = d ** -0.5
    quantized = k_scale is not None
    operands = (q, k_pages, v_pages) + (
        (k_scale, v_scale) if quantized else ())
    return pl.pallas_call(
        functools.partial(
            _paged_decode_kernel, page_size=p, n_pages=m, window=window,
            scale=scale, groups=groups, quantized=quantized),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, h, d),
                             lambda i, j, pos_ref, tab_ref: (i, 0, 0)),
                pl.BlockSpec((1, p, kv, d),
                             lambda i, j, pos_ref, tab_ref:
                             (tab_ref[i, j], 0, 0, 0)),
                pl.BlockSpec((1, p, kv, d),
                             lambda i, j, pos_ref, tab_ref:
                             (tab_ref[i, j], 0, 0, 0)),
                *_scale_specs(quantized, p, kv),
            ],
            out_specs=pl.BlockSpec((1, h, d),
                                   lambda i, j, pos_ref, tab_ref: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, groups), jnp.float32),
                pltpu.VMEM((kv, groups), jnp.float32),
                pltpu.VMEM((kv, groups, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(pos, page_table, *operands)
