"""Flash attention Pallas kernel (training/prefill; causal / SWA / bidir).

Online-softmax blockwise attention: q blocks stay resident in VMEM while
k/v blocks stream HBM->VMEM; the running (max, sum, acc) state lives in
VMEM scratch across the kv grid dimension. Scores are computed on the MXU
(q@k^T as a (block_q x d) x (d x block_k) matmul, fp32 accumulation),
masking is positional (no (S x S) mask tensor ever exists — the paper's
software-managed-memory discipline).

Layout: (B*H, S, D) — heads flattened into the grid's leading dimension.
Block sizes default to 128 (MXU-aligned); head_dim rides as the minor
dimension (Mosaic pads to lanes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, n_k: int, causal: bool,
                  window: Optional[int], scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    allowed = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        allowed &= qpos >= kpos
    if window is not None:
        allowed &= (qpos - kpos) < window
    scores = jnp.where(allowed, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    p = jnp.exp(scores - m_new[:, None])
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * correction[:, None] + pv
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """q,k,v: (BH, S, D) -> (BH, S, D)."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} not divisible by blocks "
                         f"({block_q},{block_k})")
    scale = d ** -0.5
    n_k = s // block_k
    grid = (bh, s // block_q, n_k)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, n_k=n_k,
            causal=causal, window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
