"""OCS-based cube scheduling, spare substitution, and availability modeling.

Paper (§Improved Resilience Over Time): since TPU v4, pods are built from
4x4x4 electrically-cabled cubes whose face links terminate on optical circuit
switches. Consequences the paper highlights, all modeled here:

  * slices need not be *contiguous*: any idle cubes can be stitched into a
    torus (vs TPU v2/v3 which needed contiguous chips);
  * failed cubes are mapped out and spare cubes substituted, restoring the
    3D torus ("Ironwood can run four of the popular 2K slice jobs ... even if
    some nodes are down, as 16 spare cubes remain available as substitutes");
  * incremental deployment: each cube enters production as soon as it is
    installed, instead of waiting for the full pod;
  * the primary availability hazard is the CPU host (4 TPUs/host).

The scheduler here is used three ways: (1) benchmarks reproducing the paper's
scheduling/availability claims, (2) the resilience subsystem's elastic
driver, which asks the scheduler for a replacement allocation after injected
failures, and (3) property tests of its invariants.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.topology import CUBE, CubeGeometry, cube_grid

CubeId = int


@dataclasses.dataclass
class SliceAllocation:
    """A scheduled slice: a set of cubes stitched into a torus by the OCS."""

    job: str
    chips: int
    cubes: Tuple[CubeId, ...]
    cube_dims: Tuple[int, int, int]  # arrangement, in cubes

    @property
    def torus_dims(self) -> Tuple[int, int, int]:
        s = CUBE.side
        a, b, c = self.cube_dims
        return (a * s, b * s, c * s)


class OCSPodScheduler:
    """Cube-granularity slice scheduler for one pod.

    ``contiguous=False`` (OCS, TPU v4+): any idle healthy cubes satisfy a
    request. ``contiguous=True`` (pre-OCS, TPU v2/v3 semantics): a request is
    satisfiable only by a *rectangular block* of idle healthy cubes inside
    the pod's physical cube grid — the paper's "locate 128 contiguous idle
    chips" difficulty, modeled at cube granularity.
    """

    def __init__(self, total_cubes: int, *, contiguous: bool = False,
                 cube: CubeGeometry = CUBE,
                 grid: Optional[Tuple[int, int, int]] = None):
        if total_cubes <= 0:
            raise ValueError("total_cubes must be positive")
        self.cube = cube
        self.total_cubes = total_cubes
        self.contiguous = contiguous
        self.grid = grid or cube_grid(total_cubes * cube.chips)
        if math.prod(self.grid) < total_cubes:
            raise ValueError(f"grid {self.grid} smaller than {total_cubes}")
        self._failed: Set[CubeId] = set()
        self._installed: Set[CubeId] = set(range(total_cubes))
        self._alloc: Dict[str, SliceAllocation] = {}
        self._cube_owner: Dict[CubeId, str] = {}
        self.reconfig_count = 0  # successful OCS substitutions

    # ------------------------------------------------------------------ api

    @property
    def allocations(self) -> Dict[str, SliceAllocation]:
        return dict(self._alloc)

    @property
    def failed_cubes(self) -> FrozenSet[CubeId]:
        return frozenset(self._failed)

    def idle_cubes(self) -> List[CubeId]:
        return [c for c in sorted(self._installed)
                if c not in self._failed and c not in self._cube_owner]

    def spare_cubes(self) -> int:
        return len(self.idle_cubes())

    # -- incremental deployment (paper: cubes usable as installed) ----------

    def set_installed(self, cubes: Sequence[CubeId]) -> None:
        bad = [c for c in cubes if not (0 <= c < self.total_cubes)]
        if bad:
            raise ValueError(f"cube ids out of range: {bad}")
        self._installed = set(cubes)

    # -- scheduling ----------------------------------------------------------

    def allocate(self, job: str, chips: int) -> Optional[SliceAllocation]:
        """Try to schedule ``chips`` (rounded up to whole cubes)."""
        if job in self._alloc:
            raise ValueError(f"job {job!r} already scheduled")
        need = self.cube.cubes_for(chips)
        idle = self.idle_cubes()
        if len(idle) < need:
            return None
        if self.contiguous:
            block = self._find_contiguous_block(need)
            if block is None:
                return None
            chosen, dims = block
        else:
            chosen = tuple(idle[:need])
            dims = cube_grid(need * self.cube.chips)
        alloc = SliceAllocation(job=job, chips=chips, cubes=tuple(chosen),
                                cube_dims=dims)
        self._alloc[job] = alloc
        for c in chosen:
            self._cube_owner[c] = job
        return alloc

    def release(self, job: str) -> None:
        alloc = self._alloc.pop(job)
        for c in alloc.cubes:
            self._cube_owner.pop(c, None)

    # -- elastic re-scale (paper: "rescheduled at smaller scale") ------------

    def max_slice_cubes(self, limit: int) -> int:
        """Largest schedulable slice size in cubes, capped at ``limit``.

        OCS pods can stitch any idle healthy cubes into a torus, so the
        answer is simply how many are idle; pre-OCS (contiguous) pods are
        bounded by the largest free rectangular block. The elastic fleet
        arm asks this before shrinking a starved job."""
        idle = len(self.idle_cubes())
        if not self.contiguous:
            return min(limit, idle)
        for n in range(min(limit, idle), 0, -1):
            if self._find_contiguous_block(n) is not None:
                return n
        return 0

    def grow(self, job: str, extra_cubes: int) -> Optional[SliceAllocation]:
        """Stitch ``extra_cubes`` idle cubes into a live allocation (an OCS
        reconfiguration — the grow-back half of elastic re-scale). Returns
        the grown allocation, or None if not enough idle cubes. Pre-OCS
        pods cannot grow in place: the block would have to stay
        rectangular, so a full reschedule is required instead."""
        alloc = self._alloc.get(job)
        if alloc is None:
            raise KeyError(job)
        if extra_cubes <= 0:
            return alloc
        if self.contiguous:
            return None
        idle = self.idle_cubes()
        if len(idle) < extra_cubes:
            return None
        added = tuple(idle[:extra_cubes])
        new_cubes = alloc.cubes + added
        chips = len(new_cubes) * self.cube.chips
        for c in added:
            self._cube_owner[c] = job
        patched = dataclasses.replace(
            alloc, cubes=new_cubes, chips=chips,
            cube_dims=cube_grid(chips, self.cube))
        self._alloc[job] = patched
        self.reconfig_count += 1
        return patched

    # -- failures & repair ----------------------------------------------------

    def fail_cube(self, cube_id: CubeId) -> Optional[str]:
        """Mark a cube failed. Returns the impacted job (if any)."""
        self._failed.add(cube_id)
        return self._cube_owner.get(cube_id)

    def repair_cube(self, cube_id: CubeId) -> None:
        self._failed.discard(cube_id)

    def fail_host(self, host_id: int, tpus_per_host: int = 4
                  ) -> Tuple[CubeId, Optional[str]]:
        """A CPU host dies (the paper's primary availability hazard).

        A host serves ``tpus_per_host`` chips, so a 64-chip cube spans
        several hosts; losing any host breaks the cube's torus, and the
        map-out granularity of the OCS is the whole cube. Returns
        (cube id, impacted job)."""
        hosts_per_cube = self.cube.chips // tpus_per_host
        cube_id = host_id // hosts_per_cube
        if not 0 <= cube_id < self.total_cubes:
            raise ValueError(f"host {host_id} outside pod")
        return cube_id, self.fail_cube(cube_id)

    def substitute(self, job: str) -> Optional[SliceAllocation]:
        """Map out failed cubes of a job, substituting idle spares (OCS
        reconfiguration). Returns the patched allocation, or None if not
        enough spares — caller must then reschedule at smaller scale
        (elastic) or wait for repair. Pre-OCS (contiguous) pods cannot
        substitute: any failure forces a full reschedule."""
        alloc = self._alloc.get(job)
        if alloc is None:
            raise KeyError(job)
        broken = [c for c in alloc.cubes if c in self._failed]
        if not broken:
            return alloc
        if self.contiguous:
            return None
        spares = self.idle_cubes()
        if len(spares) < len(broken):
            return None
        replacement = dict(zip(broken, spares))
        new_cubes = tuple(replacement.get(c, c) for c in alloc.cubes)
        for c in broken:
            self._cube_owner.pop(c, None)
        for c in replacement.values():
            self._cube_owner[c] = job
        patched = dataclasses.replace(alloc, cubes=new_cubes)
        self._alloc[job] = patched
        self.reconfig_count += 1
        return patched

    # -- invariants (property tests / fleet simulator) -----------------------

    def check_invariants(self) -> None:
        """Raise AssertionError if the allocation state is inconsistent.

        Pinned invariants: no two live slices share a cube; the ownership
        index agrees with the allocations; allocations only use installed
        cubes; every owned cube belongs to a live allocation."""
        seen: Dict[CubeId, str] = {}
        for job, alloc in self._alloc.items():
            for c in alloc.cubes:
                assert c not in seen, \
                    f"cube {c} shared by {seen[c]!r} and {job!r}"
                seen[c] = job
                assert c in self._installed, f"cube {c} not installed"
                assert self._cube_owner.get(c) == job, \
                    f"owner index disagrees for cube {c}"
        assert set(self._cube_owner) == set(seen), \
            "ownership index has stale entries"

    # -- contiguous-mode block search -----------------------------------------

    def _find_contiguous_block(
        self, need: int
    ) -> Optional[Tuple[Tuple[CubeId, ...], Tuple[int, int, int]]]:
        gx, gy, gz = self.grid

        def cube_id(x: int, y: int, z: int) -> CubeId:
            return (x * gy + y) * gz + z

        free = {c for c in self.idle_cubes()}
        # enumerate factorizations of `need` into block dims, prefer balanced
        dims_opts = []
        for a in range(1, need + 1):
            if need % a:
                continue
            for b in range(1, need // a + 1):
                if (need // a) % b:
                    continue
                c = need // a // b
                dims_opts.append((a, b, c))
        dims_opts.sort(key=lambda d: max(d) / min(d))
        for (dx, dy, dz) in dims_opts:
            if dx > gx or dy > gy or dz > gz:
                continue
            for x0 in range(gx - dx + 1):
                for y0 in range(gy - dy + 1):
                    for z0 in range(gz - dz + 1):
                        ids = [cube_id(x0 + i, y0 + j, z0 + k)
                               for i in range(dx)
                               for j in range(dy)
                               for k in range(dz)]
                        if all(i in free and i < self.total_cubes
                               for i in ids):
                            return tuple(sorted(ids)), (dx, dy, dz)
        return None


# ---------------------------------------------------------------------------
# Availability / goodput models (paper §Resilience).
# ---------------------------------------------------------------------------


def slice_availability(host_availability: float, chips: int,
                       tpus_per_host: int = 4) -> float:
    """P(all hosts of a synchronous slice are up) = a^(hosts).

    Paper: "Without OCSes, host availability must be >99.9% to achieve high
    slice goodput" — an Ironwood pod has 2304 hosts.
    """
    hosts = -(-chips // tpus_per_host)
    return host_availability**hosts


def schedulable_jobs(total_cubes: int, failed_cubes: int, job_chips: int,
                     cube: CubeGeometry = CUBE) -> int:
    """How many jobs of ``job_chips`` fit with OCS (no contiguity needed)."""
    healthy = total_cubes - failed_cubes
    per_job = cube.cubes_for(job_chips)
    return healthy // per_job


def monte_carlo_contiguous_vs_ocs(
    total_cubes: int,
    job_cubes: int,
    busy_fraction: float,
    trials: int,
    seed: int = 0,
    grid: Optional[Tuple[int, int, int]] = None,
) -> Dict[str, float]:
    """P(schedule success) for a job of ``job_cubes`` when a random
    ``busy_fraction`` of cubes is already occupied — OCS vs contiguous.

    Reproduces the paper's point that "the difficulty of scheduling increases
    sharply with slice size" without OCS.
    """
    rng = np.random.default_rng(seed)
    ok_ocs = ok_contig = 0
    for _ in range(trials):
        busy = rng.random(total_cubes) < busy_fraction
        idle = int((~busy).sum())
        if idle >= job_cubes:
            ok_ocs += 1
        sched = OCSPodScheduler(total_cubes, contiguous=True, grid=grid)
        # mark busy cubes as failed (equivalent: unavailable)
        for c in np.flatnonzero(busy):
            sched.fail_cube(int(c))
        if sched.allocate("probe", job_cubes * CUBE.chips) is not None:
            ok_contig += 1
    return {
        "p_success_ocs": ok_ocs / trials,
        "p_success_contiguous": ok_contig / trials,
        "trials": float(trials),
    }
