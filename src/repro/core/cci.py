"""Performance-per-Watt (Figure 5) and Compute Carbon Intensity (Figure 6).

The paper (following [Vahdat24] and [Schneider25]) advocates two metrics:

  * performance per (TDP) Watt — Figure 5 gives the relative values
    1 / 1.8 / 4.9 / 5.2 / 29.3 for TPU v2..Ironwood (Table 1 bottom rows);
  * compute carbon intensity (CCI) — gCO2e per utilized ExaFLOP, split into
    operational + embodied. Figure 6 gives CCI for TPU v4, v5p, Ironwood.

Figure 6's bar values are reconstructed here from every number the paper
states in prose, and the reconstruction is over-constrained — tests check
all of the paper's stated relations simultaneously:
  - overall & operational CCI: v4/v5p = 1.1x, embodied v4/v5p = 1.3x;
  - Ironwood operational jump ~3.7x vs v5p, embodied ~3.8x;
  - TPU v5p total CCI = 265 g/EFLOP (the GPT-3 worked example);
  - operational share ~75% of total for all three (market-based);
  - footnote 7: location-based operational CCI = 793 / 712 / 195, under
    which Ironwood's embodied share drops from ~23% to ~8%.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core import hwspec


@dataclasses.dataclass(frozen=True)
class CCIRecord:
    """CCI in gCO2e per ExaFLOP (10**18 utilized FLOPs)."""

    tpu: str
    operational_market: float  # credits carbon-free energy purchases
    embodied: float
    operational_location: float  # excludes CFE purchases (footnote 7)

    @property
    def total_market(self) -> float:
        return self.operational_market + self.embodied

    @property
    def total_location(self) -> float:
        return self.operational_location + self.embodied

    @property
    def embodied_share_market(self) -> float:
        return self.embodied / self.total_market

    @property
    def embodied_share_location(self) -> float:
        return self.embodied / self.total_location


# Figure 6 reconstruction (see module docstring). Units: gCO2e / EFLOP.
CCI_TPU_V4 = CCIRecord("tpu_v4", operational_market=219.0, embodied=86.0,
                       operational_location=793.0)
CCI_TPU_V5P = CCIRecord("tpu_v5p", operational_market=199.0, embodied=66.0,
                        operational_location=712.0)
CCI_IRONWOOD = CCIRecord("ironwood", operational_market=54.0, embodied=17.4,
                         operational_location=195.0)

CCI_TABLE: Tuple[CCIRecord, ...] = (CCI_TPU_V4, CCI_TPU_V5P, CCI_IRONWOOD)
CCI_BY_NAME: Dict[str, CCIRecord] = {r.tpu: r for r in CCI_TABLE}


def perf_per_watt_relative() -> Dict[str, float]:
    """Figure 5: relative peak performance per TDP Watt, TPU v2 = 1.

    Recomputed from Table 1's Relative Pod TFLOPS / Relative Pod TDP so the
    two rows' consistency is itself checked (they must reproduce the
    Relative Pod TFLOPS/W row)."""
    out = {}
    for spec in hwspec.GENERATIONS:
        out[spec.name] = spec.rel_pod_tflops / spec.rel_pod_tdp
    return out


def emissions_grams(flops: float, cci: CCIRecord, *,
                    market: bool = True) -> float:
    """Ballpark emissions for a task of ``flops`` utilized FLOPs (paper's
    GPT-3 example: 3.14e23 FLOPs * 265 g/EFLOP = ~8.3e7 gCO2e ~= 83 tCO2e).

    (The paper's prose converts 83e6 g to "83 million metric tons"; that is
    a unit slip — 83e6 g is 83 metric tons. We reproduce the 8.3e7 g figure.)
    """
    per_eflop = cci.total_market if market else cci.total_location
    return flops / 1e18 * per_eflop


def operational_cci_from_perf_per_watt(
    electricity_gco2e_per_kwh: float, flops_per_watt: float
) -> float:
    """Paper identity: operational CCI = emissions factor / (perf/Watt).

    flops_per_watt is measured FLOP/s per Watt; returns gCO2e/EFLOP.
    1 kWh = 3.6e6 J, so FLOPs per kWh = flops_per_watt * 3.6e6.
    """
    flops_per_kwh = flops_per_watt * 3.6e6
    return electricity_gco2e_per_kwh / flops_per_kwh * 1e18


@dataclasses.dataclass
class CarbonLedger:
    """Attachable to a training run: integrates utilized FLOPs into gCO2e.

    Uses the target generation's CCI; ``utilization`` discounts peak to
    realized FLOP/s (CCI is per *utilized* FLOP, so emissions depend only on
    total useful FLOPs — utilization affects wall time, not grams)."""

    cci: CCIRecord
    flops_accum: float = 0.0

    def record_step(self, useful_flops: float) -> None:
        if useful_flops < 0:
            raise ValueError("negative flops")
        self.flops_accum += useful_flops

    @property
    def grams_co2e(self) -> float:
        return emissions_grams(self.flops_accum, self.cci)

    def summary(self) -> Dict[str, float]:
        return {
            "flops": self.flops_accum,
            "gco2e_market": self.grams_co2e,
            "gco2e_location": emissions_grams(
                self.flops_accum, self.cci, market=False
            ),
        }
