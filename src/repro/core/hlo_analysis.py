"""Post-SPMD HLO text analysis: FLOPs, HBM bytes, and collective traffic.

Why not ``compiled.cost_analysis()``? Two reasons, both verified empirically
on this JAX/XLA build:

  1. XLA's HloCostAnalysis visits ``while`` bodies ONCE — a 61-layer
     ``lax.scan`` transformer would be undercounted ~61x. XLA:CPU annotates
     every while with ``backend_config={"known_trip_count":{"n":...}}``, so
     we propagate trip-count multipliers through the call graph ourselves.
  2. cost_analysis has no collective accounting at all; the roofline's
     collective term needs per-op bytes *and* the mesh axis each collective
     runs over (parsed from ``replica_groups``, including the iota
     ``[G,S]<=[dims]T(perm)`` format).

The parser understands the post-optimization HLO text of ``compiled
.as_text()``. Byte accounting is at fusion granularity — a fusion's HBM
traffic is its operands + result (internals live in registers/VMEM), which
matches how a TPU executes it. Dynamic-slice reads and dynamic-update-slice
writes inside scan bodies are counted at slice granularity, not full-buffer
granularity (otherwise scans over stacked weights would overcount L^2).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

DTYPE_BYTES: Dict[str, float] = {
    "pred": 1, "s2": 0.25, "s4": 0.5, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u2": 0.25, "u4": 0.5, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\("
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)")

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}
# Ops that move no HBM bytes themselves.
FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "add-dependency", "domain", "opt-barrier",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
}


def shape_bytes(type_str: str) -> float:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _result_dims(type_str: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dtype, shape


@dataclasses.dataclass
class HloOp:
    name: str
    opcode: str
    result_type: str
    operands: Tuple[str, ...]
    attrs: str
    comp: str

    @property
    def result_bytes(self) -> float:
        return shape_bytes(self.result_type)


@dataclasses.dataclass
class HloComputation:
    name: str
    is_entry: bool
    params: Dict[str, str]  # name -> type string
    ops: List[HloOp] = dataclasses.field(default_factory=list)

    def op_map(self) -> Dict[str, HloOp]:
        return {o.name: o for o in self.ops}


@dataclasses.dataclass
class CollectiveRecord:
    opcode: str
    comp: str
    result_bytes: float
    operand_bytes: float
    group_size: int
    groups: Tuple[Tuple[int, ...], ...]
    multiplier: float
    axes: Tuple[str, ...]  # mesh axes this collective spans ("?" if unknown)

    @property
    def total_result_bytes(self) -> float:
        return self.result_bytes * self.multiplier

    @property
    def total_operand_bytes(self) -> float:
        return self.operand_bytes * self.multiplier


@dataclasses.dataclass
class HloCostReport:
    """Trip-count-aware cost summary of one compiled partition program."""

    flops: float  # per-device FLOPs (dots + convs), trip-count scaled
    hbm_bytes: float  # per-device approximate HBM traffic
    collectives: List[CollectiveRecord]
    peak_memory_bytes: float  # from memory_analysis (argument+output+temp)
    dot_flops_by_comp: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def collective_bytes(self) -> float:
        return sum(c.total_operand_bytes for c in self.collectives)

    def collective_bytes_by_axes(self) -> Dict[Tuple[str, ...], float]:
        out: Dict[Tuple[str, ...], float] = {}
        for c in self.collectives:
            out[c.axes] = out.get(c.axes, 0.0) + c.total_operand_bytes
        return out


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------


def parse_hlo_module(text: str) -> Dict[str, HloComputation]:
    comps: Dict[str, HloComputation] = {}
    current: Optional[HloComputation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):  # computation header or module line
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.endswith("{"):
                is_entry = bool(hdr.group(1))
                name = hdr.group(2)
                params: Dict[str, str] = {}
                for pname, ptype in _PARAM_RE.findall(hdr.group(3)):
                    params[pname] = ptype.strip()
                current = HloComputation(name, is_entry, params)
                comps[name] = current
            elif line.startswith("}"):
                current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        # operand region: text between the opcode's '(' and its matching ')'
        start = m.end()
        depth, i = 1, start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operand_str = line[start:i - 1]
        attrs = line[i:]
        operands = tuple(_OPERAND_RE.findall(operand_str))
        current.ops.append(
            HloOp(name, opcode, rtype, operands, attrs, current.name))
    return comps


# ---------------------------------------------------------------------------
# Multiplier propagation (trip counts through the call graph)
# ---------------------------------------------------------------------------


def _comp_multipliers(comps: Dict[str, HloComputation],
                      default_trip: int = 1) -> Dict[str, float]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    # DFS from entry; the call graph is a DAG.
    order: List[str] = []
    seen: Set[str] = set()

    def edges(comp: HloComputation) -> Iterable[Tuple[str, float]]:
        for op in comp.ops:
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.attrs)
                trip = float(tm.group(1)) if tm else float(default_trip)
                for cm in _CALL_ATTR_RE.finditer(op.attrs):
                    attr = cm.group(0)
                    callee = cm.group(1)
                    if callee in comps:
                        yield callee, trip if attr.startswith("body") else trip
            else:
                for cm in _CALL_ATTR_RE.finditer(op.attrs):
                    callee = cm.group(1)
                    if callee in comps:
                        yield callee, 1.0
                br = _BRANCH_RE.search(op.attrs)
                if br:
                    for callee in _OPERAND_RE.findall(br.group(1)):
                        if callee in comps:
                            yield callee, 1.0

    def dfs(name: str) -> None:
        if name in seen:
            return
        seen.add(name)
        for callee, _ in edges(comps[name]):
            dfs(callee)
        order.append(name)

    dfs(entry.name)
    for name in reversed(order):  # callers before callees
        for callee, factor in edges(comps[name]):
            mult[callee] += mult[name] * factor
    return mult


def _controlflow_comps(comps: Dict[str, HloComputation]) -> Set[str]:
    """Computations whose top-level ops materialize to HBM: the entry, while
    bodies/conds, and conditional branches (NOT fusion/reducer bodies)."""
    out = {c.name for c in comps.values() if c.is_entry}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                for cm in _CALL_ATTR_RE.finditer(op.attrs):
                    out.add(cm.group(1))
            elif op.opcode == "conditional":
                br = _BRANCH_RE.search(op.attrs)
                if br:
                    out.update(_OPERAND_RE.findall(br.group(1)))
    return out


# ---------------------------------------------------------------------------
# FLOP counting
# ---------------------------------------------------------------------------

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(op: HloOp, type_of: Dict[str, str]) -> float:
    res = _result_dims(op.result_type)
    if res is None:
        return 0.0
    _, rshape = res
    out_elems = math.prod(rshape) if rshape else 1
    contract = 1
    cm = _CONTRACT_RE.search(op.attrs)
    lhs_type = type_of.get(op.operands[0], "") if op.operands else ""
    lres = _result_dims(lhs_type)
    if cm and lres is not None:
        _, lshape = lres
        dims = [int(d) for d in cm.group(1).split(",") if d]
        for d in dims:
            if d < len(lshape):
                contract *= lshape[d]
    return 2.0 * out_elems * contract


def _conv_flops(op: HloOp, type_of: Dict[str, str]) -> float:
    # rough: 2 * output elems * (kernel elems / output-channels-contribution)
    res = _result_dims(op.result_type)
    if res is None or len(op.operands) < 2:
        return 0.0
    _, rshape = res
    kres = _result_dims(type_of.get(op.operands[1], ""))
    if kres is None:
        return 0.0
    _, kshape = kres
    out_elems = math.prod(rshape) if rshape else 1
    # kernel shape [out_c, in_c, *spatial] or similar: contraction size =
    # total kernel elems / out_channels; use max dim as out_channels guess.
    kelems = math.prod(kshape) if kshape else 1
    out_c = kshape[-1] if kshape else 1
    return 2.0 * out_elems * max(1, kelems // max(1, out_c))


# ---------------------------------------------------------------------------
# Byte counting
# ---------------------------------------------------------------------------


def _op_bytes(op: HloOp, type_of: Dict[str, str],
              comps: Dict[str, HloComputation]) -> float:
    if op.opcode in FREE_OPS:
        return 0.0
    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * op.result_bytes  # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = shape_bytes(type_of.get(op.operands[1], "")) if len(
            op.operands) > 1 else op.result_bytes
        return 2.0 * upd  # read update + write slice region (in place)
    if op.opcode == "fusion":
        return _fusion_bytes(op, type_of, comps)
    if op.opcode.startswith("all-") or op.opcode in COLLECTIVE_OPS:
        # collective data movement is costed separately; HBM side: read
        # operand + write result once.
        opb = sum(shape_bytes(type_of.get(o, "")) for o in op.operands)
        return opb + op.result_bytes
    opb = sum(shape_bytes(type_of.get(o, "")) for o in op.operands)
    return opb + op.result_bytes


_PASSTHRU = {"convert", "copy", "bitcast", "reshape", "transpose", "negate",
             "bitcast-convert"}


def _fusion_bytes(op: HloOp, type_of: Dict[str, str],
                  comps: Dict[str, HloComputation]) -> float:
    """HBM traffic of a fusion: operands + result, but slice-granular when a
    big operand is only dynamic-sliced inside (scan weight/stash access) and
    update-granular when the fusion performs an in-place
    dynamic-update-slice. Pass-through elementwise chains (convert/copy/
    bitcast/...) between the param and the (d)us are followed."""
    cm = re.search(r"calls=%([\w.\-]+)", op.attrs)
    callee = comps.get(cm.group(1)) if cm else None
    if callee is None:
        opb = sum(shape_bytes(type_of.get(o, "")) for o in op.operands)
        return opb + op.result_bytes
    param_names = list(callee.params)
    inner = callee.op_map()
    consumers: Dict[str, List[HloOp]] = {}
    for iop in callee.ops:
        for o in iop.operands:
            consumers.setdefault(o, []).append(iop)

    def bytes_of(name: str) -> float:
        if name in callee.params:
            return shape_bytes(callee.params[name])
        if name in inner:
            return inner[name].result_bytes
        return 0.0

    def param_contribution(pname: str) -> float:
        full = bytes_of(pname)
        total = 0.0
        seen: set = set()
        frontier = [pname]
        while frontier:
            cur = frontier.pop()
            for c in consumers.get(cur, []):
                if c.name in seen:
                    continue
                seen.add(c.name)
                if c.opcode == "dynamic-slice":
                    total += c.result_bytes
                elif (c.opcode == "dynamic-update-slice"
                      and c.operands and c.operands[0] == cur):
                    pass  # in-place target; write costed at the root
                elif c.opcode in _PASSTHRU:
                    frontier.append(c.name)
                else:
                    return full  # materially consumed
        return min(total, full)

    total = 0.0
    for idx, pname in enumerate(param_names):
        contrib = param_contribution(pname)
        if contrib == bytes_of(pname) and idx < len(op.operands):
            # use the caller-side operand size (authoritative sharded size)
            contrib = shape_bytes(type_of.get(op.operands[idx], "")) or contrib
        total += contrib

    # root side: follow pass-through back to a dynamic-update-slice
    r = callee.ops[-1] if callee.ops else None
    hops = 0
    while (r is not None and r.opcode in _PASSTHRU and r.operands
           and hops < 8):
        r = inner.get(r.operands[0])
        hops += 1
    if r is not None and r.opcode == "dynamic-update-slice" \
            and len(r.operands) > 1:
        total += 2.0 * bytes_of(r.operands[1])  # read update + write region
    else:
        total += op.result_bytes
    return total


# ---------------------------------------------------------------------------
# Collective group -> mesh-axis attribution
# ---------------------------------------------------------------------------

_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})\}")


def parse_replica_groups(attrs: str) -> Optional[Tuple[Tuple[int, ...], ...]]:
    m = _IOTA_GROUPS_RE.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = tuple(int(d) for d in m.group(3).split(","))
        ids = np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            perm = tuple(int(d) for d in m.group(4).split(","))
            ids = ids.transpose(perm)
        ids = ids.reshape(g, s)
        return tuple(tuple(int(x) for x in row) for row in ids)
    m = _LIST_GROUPS_RE.search(attrs)
    if m:
        groups = []
        for grp in re.findall(r"\{([\d, ]*)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids:
                groups.append(tuple(ids))
        return tuple(groups) if groups else None
    return None


def axes_for_groups(
    groups: Tuple[Tuple[int, ...], ...],
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
) -> Tuple[str, ...]:
    """Which subset of mesh axes a replica-group partition spans."""
    n_dev = math.prod(mesh_shape)
    ids = np.arange(n_dev).reshape(tuple(mesh_shape))
    want: FrozenSet[FrozenSet[int]] = frozenset(
        frozenset(g) for g in groups)
    naxes = len(mesh_shape)
    # check subsets from smallest to largest
    from itertools import combinations
    for r in range(1, naxes + 1):
        for subset in combinations(range(naxes), r):
            moved = ids.transpose(
                [a for a in range(naxes) if a not in subset] + list(subset))
            grp_size = math.prod(mesh_shape[a] for a in subset)
            cand = moved.reshape(-1, grp_size)
            got = frozenset(frozenset(int(x) for x in row) for row in cand)
            if got == want:
                return tuple(axis_names[a] for a in subset)
    return ("?",)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def analyze_compiled_text(
    text: str,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    *,
    default_trip: int = 1,
    peak_memory_bytes: float = 0.0,
) -> HloCostReport:
    comps = parse_hlo_module(text)
    mult = _comp_multipliers(comps, default_trip)
    cf_comps = _controlflow_comps(comps)

    # symbol table per computation: op name -> result type (incl. params)
    flops = 0.0
    hbm = 0.0
    dot_by_comp: Dict[str, float] = {}
    collectives: List[CollectiveRecord] = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        type_of: Dict[str, str] = dict(comp.params)
        for op in comp.ops:
            type_of[op.name] = op.result_type
        for op in comp.ops:
            if op.opcode == "dot":
                f = _dot_flops(op, type_of) * m
                flops += f
                dot_by_comp[comp.name] = dot_by_comp.get(comp.name, 0.0) + f
            elif op.opcode == "convolution":
                f = _conv_flops(op, type_of) * m
                flops += f
                dot_by_comp[comp.name] = dot_by_comp.get(comp.name, 0.0) + f
            if comp.name in cf_comps:
                hbm += _op_bytes(op, type_of, comps) * m
                base = op.opcode.replace("-start", "")
                if base in COLLECTIVE_OPS:
                    groups = parse_replica_groups(op.attrs)
                    gsize = len(groups[0]) if groups else 1
                    axes = (axes_for_groups(groups, mesh_shape, axis_names)
                            if groups else ("?",))
                    opb = sum(shape_bytes(type_of.get(o, ""))
                              for o in op.operands)
                    collectives.append(CollectiveRecord(
                        opcode=base, comp=comp.name,
                        result_bytes=op.result_bytes,
                        operand_bytes=opb or op.result_bytes,
                        group_size=gsize, groups=groups or ((0,),),
                        multiplier=m, axes=axes))
    return HloCostReport(flops=flops, hbm_bytes=hbm, collectives=collectives,
                         peak_memory_bytes=peak_memory_bytes,
                         dot_flops_by_comp=dot_by_comp)
