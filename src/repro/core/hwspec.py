"""Hardware specifications for five generations of TPU training supercomputers.

This module encodes Table 1 of the paper as typed data, plus TPU v5e (the
roofline TARGET for this repo's dry-runs, per the task spec). Everything the
paper derives from Table 1 — scaling ratios, bisection bandwidth, pod peak
ExaFLOPS, relative perf/W — is recomputed from these records by
``benchmarks/bench_table1.py`` and checked against the paper's claims in
tests.

Units follow the paper: TFLOPS are peak per chip; HBM BW GB/s per chip; ICI
link BW GB/s *per direction* (the paper's footnote 4); pod bisection GB/s.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MXUSpec:
    """Matrix-multiply unit configuration (systolic arrays)."""

    count: int
    rows: int
    cols: int
    dtype: str  # "bf16" or "fp8"

    @property
    def macs_per_cycle(self) -> int:
        return self.count * self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """One generation of training TPU (one column of Table 1)."""

    name: str
    year: int
    peak_bf16_tflops: float
    peak_fp8_tflops: Optional[float]  # None -> N.A. in the paper's table
    mxus: Tuple[MXUSpec, ...]
    vmem_mib: int
    hbm_version: str
    hbm_stacks: int
    hbm_gib: int
    hbm_gbps: float
    tensorcores: int
    sparsecores: int
    cooling: str  # "air" | "liquid"
    tpus_per_host: int
    pod_size: int
    pod_topology: str  # "2d_torus" | "3d_torus"
    ici_links: int
    ici_link_gbps: float
    # Relative rows of Table 1 (normalized to TPU v2 = 1).
    rel_pod_tflops: float  # normalized FP8 FLOPS
    rel_pod_tflops_per_watt: float  # per TDP watt
    rel_pod_tdp: float

    # ----- Derived quantities (the paper computes these from the above) -----

    @property
    def peak_tflops(self) -> float:
        """Best peak (FP8 if supported, else BF16) — Table 1 normalization."""
        return self.peak_fp8_tflops or self.peak_bf16_tflops

    @property
    def torus_dims(self) -> Tuple[int, ...]:
        """Torus shape. The paper gives pod size + topology; we use the
        deployed geometries (v2: 16x16, v3: 32x32, v4: 16x16x16,
        v5p: 16x20x28, Ironwood: 16x24x24)."""
        known: Dict[str, Tuple[int, ...]] = {
            "tpu_v2": (16, 16),
            "tpu_v3": (32, 32),
            "tpu_v4": (16, 16, 16),
            "tpu_v5p": (16, 20, 28),
            "ironwood": (16, 24, 24),
            "tpu_v5e": (16, 16),
        }
        if self.name in known:
            return known[self.name]
        # Fallback: balanced torus of the right dimensionality.
        ndims = 2 if self.pod_topology == "2d_torus" else 3
        side = round(self.pod_size ** (1.0 / ndims))
        return (side,) * ndims

    @property
    def pod_bisection_gbps(self) -> float:
        """Bisection bandwidth of the pod torus (GB/s, per direction).

        For a torus cut across its *longest* dimension the bisection crosses
        2 * (pod_size / longest_dim) links (wraparound doubles the cut).
        """
        dims = self.torus_dims
        longest = max(dims)
        cross_section = self.pod_size // longest  # nodes per "plane"
        return 2.0 * cross_section * self.ici_link_gbps

    @property
    def pod_peak_bf16_exaflops(self) -> float:
        return self.pod_size * self.peak_bf16_tflops / 1e6

    @property
    def pod_peak_fp8_exaflops(self) -> Optional[float]:
        if self.peak_fp8_tflops is None:
            return None
        return self.pod_size * self.peak_fp8_tflops / 1e6

    @property
    def pod_hbm_gib(self) -> float:
        """Pod-level directly addressable HBM in GiB. The paper's Table 1 row
        "Pod HBM Capacity" is this value / 1000 (e.g. Ironwood 1769472 GiB ->
        "1769"), mixing binary chip capacity with decimal pod units."""
        return float(self.pod_size * self.hbm_gib)

    @property
    def pod_hbm_table_units(self) -> float:
        """Table-1 convention: pod HBM in thousands of GiB."""
        return self.pod_hbm_gib / 1000.0

    @property
    def hosts_per_pod(self) -> int:
        return self.pod_size // self.tpus_per_host

    def matmul_peak_flops_per_cycle(self, dtype: str = "bf16") -> int:
        """2 * MACs/cycle for the MXUs of the given dtype."""
        return sum(2 * m.macs_per_cycle for m in self.mxus if m.dtype == dtype)


# --------------------------------------------------------------------------
# Table 1, verbatim.
# --------------------------------------------------------------------------

TPU_V2 = TPUSpec(
    name="tpu_v2", year=2017,
    peak_bf16_tflops=46.0, peak_fp8_tflops=None,
    mxus=(MXUSpec(2, 128, 128, "bf16"),),
    vmem_mib=32, hbm_version="HBM2", hbm_stacks=2, hbm_gib=16, hbm_gbps=700.0,
    tensorcores=2, sparsecores=2, cooling="air", tpus_per_host=4,
    pod_size=256, pod_topology="2d_torus", ici_links=4, ici_link_gbps=62.0,
    rel_pod_tflops=1.0, rel_pod_tflops_per_watt=1.0, rel_pod_tdp=1.0,
)

TPU_V3 = TPUSpec(
    name="tpu_v3", year=2018,
    peak_bf16_tflops=123.0, peak_fp8_tflops=None,
    mxus=(MXUSpec(4, 128, 128, "bf16"),),
    vmem_mib=32, hbm_version="HBM2", hbm_stacks=4, hbm_gib=32, hbm_gbps=900.0,
    tensorcores=2, sparsecores=2, cooling="liquid", tpus_per_host=8,
    pod_size=1024, pod_topology="2d_torus", ici_links=4, ici_link_gbps=70.0,
    rel_pod_tflops=10.0, rel_pod_tflops_per_watt=1.8, rel_pod_tdp=5.6,
)

TPU_V4 = TPUSpec(
    name="tpu_v4", year=2021,
    peak_bf16_tflops=275.0, peak_fp8_tflops=None,
    mxus=(MXUSpec(8, 128, 128, "bf16"),),
    vmem_mib=32, hbm_version="HBM2", hbm_stacks=4, hbm_gib=32, hbm_gbps=1200.0,
    tensorcores=2, sparsecores=4, cooling="liquid", tpus_per_host=4,
    pod_size=4096, pod_topology="3d_torus", ici_links=6, ici_link_gbps=50.0,
    rel_pod_tflops=100.0, rel_pod_tflops_per_watt=4.9, rel_pod_tdp=20.0,
)

TPU_V5P = TPUSpec(
    name="tpu_v5p", year=2023,
    peak_bf16_tflops=459.0, peak_fp8_tflops=459.0,
    mxus=(MXUSpec(8, 128, 128, "bf16"),),
    vmem_mib=128, hbm_version="HBM2E", hbm_stacks=6, hbm_gib=96,
    hbm_gbps=2765.0,
    tensorcores=2, sparsecores=4, cooling="liquid", tpus_per_host=4,
    pod_size=8960, pod_topology="3d_torus", ici_links=6, ici_link_gbps=100.0,
    rel_pod_tflops=350.0, rel_pod_tflops_per_watt=5.2, rel_pod_tdp=67.0,
)

IRONWOOD = TPUSpec(
    name="ironwood", year=2025,
    peak_bf16_tflops=2307.0, peak_fp8_tflops=4614.0,
    mxus=(MXUSpec(4, 256, 256, "bf16"), MXUSpec(4, 512, 512, "fp8")),
    vmem_mib=128, hbm_version="HBM3E", hbm_stacks=8, hbm_gib=192,
    hbm_gbps=7300.0,
    tensorcores=2, sparsecores=4, cooling="liquid", tpus_per_host=4,
    pod_size=9216, pod_topology="3d_torus", ici_links=6, ici_link_gbps=100.0,
    rel_pod_tflops=3600.0, rel_pod_tflops_per_watt=29.3, rel_pod_tdp=123.0,
)

# The dry-run/roofline TARGET for this repo (per task spec): TPU v5e.
# 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI; 16 GiB HBM;
# 256-chip pod, 2D torus (16x16), 4 ICI links.
TPU_V5E = TPUSpec(
    name="tpu_v5e", year=2023,
    peak_bf16_tflops=197.0, peak_fp8_tflops=394.0,
    mxus=(MXUSpec(4, 128, 128, "bf16"),),
    vmem_mib=128, hbm_version="HBM2E", hbm_stacks=4, hbm_gib=16,
    hbm_gbps=819.0,
    tensorcores=1, sparsecores=4, cooling="air", tpus_per_host=4,
    pod_size=256, pod_topology="2d_torus", ici_links=4, ici_link_gbps=50.0,
    rel_pod_tflops=float("nan"), rel_pod_tflops_per_watt=float("nan"),
    rel_pod_tdp=float("nan"),
)

# Absolute TDP anchor for the paper's *relative* TDP row. The paper
# normalizes Pod TDP to TPU v2 = 1 and never states watts; the public TPU v2
# chip TDP (280 W) anchors the scale so the fleet simulator can integrate
# joules. Every other generation's absolute TDP is derived from its
# rel_pod_tdp, keeping the paper's ratios exact by construction.
TPU_V2_CHIP_TDP_W = 280.0
TPU_V2_POD_TDP_W = TPU_V2_CHIP_TDP_W * 256  # 71.68 kW


def pod_tdp_watts(spec: TPUSpec) -> Optional[float]:
    """Absolute pod TDP in watts (None when the paper gives no relative
    TDP for this part, e.g. TPU v5e)."""
    if math.isnan(spec.rel_pod_tdp):
        return None
    return spec.rel_pod_tdp * TPU_V2_POD_TDP_W


def chip_tdp_watts(spec: TPUSpec) -> Optional[float]:
    pod = pod_tdp_watts(spec)
    return None if pod is None else pod / spec.pod_size


GENERATIONS: Tuple[TPUSpec, ...] = (TPU_V2, TPU_V3, TPU_V4, TPU_V5P, IRONWOOD)

BY_NAME: Dict[str, TPUSpec] = {s.name: s for s in GENERATIONS + (TPU_V5E,)}


def get(name: str) -> TPUSpec:
    try:
        return BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown TPU generation {name!r}; have {sorted(BY_NAME)}"
        ) from None


def scaling_summary() -> Dict[str, float]:
    """Re-derive the paper's headline scaling claims from Table 1 data.

    Returns ratios Ironwood / TPU v2 (8 years):
      ~10x HBM capacity & bandwidth per node, ~100x peak node perf (fp8 vs
      bf16 normalization), ~3600x pod perf, ~36x pod size, ~39x bisection,
      ~400x pod HBM, ~30x perf/W.
    """
    v2, iw = TPU_V2, IRONWOOD
    return {
        "hbm_capacity_x": iw.hbm_gib / v2.hbm_gib,
        "hbm_bandwidth_x": iw.hbm_gbps / v2.hbm_gbps,
        "node_peak_x": iw.peak_tflops / v2.peak_tflops,
        "node_peak_bf16_x": iw.peak_bf16_tflops / v2.peak_bf16_tflops,
        "pod_size_x": iw.pod_size / v2.pod_size,
        "bisection_x": iw.pod_bisection_gbps / v2.pod_bisection_gbps,
        "pod_hbm_x": (iw.pod_size * iw.hbm_gib) / (v2.pod_size * v2.hbm_gib),
        "pod_peak_x": (iw.pod_size * iw.peak_tflops)
        / (v2.pod_size * v2.peak_tflops),
        "perf_per_watt_x": iw.rel_pod_tflops_per_watt
        / v2.rel_pod_tflops_per_watt,
        "cagr_pod_peak": (
            (iw.pod_size * iw.peak_tflops) / (v2.pod_size * v2.peak_tflops)
        ) ** (1.0 / (iw.year - v2.year)) - 1.0,
    }


@dataclasses.dataclass(frozen=True)
class RooflineTarget:
    """Per-chip constants used by the 3-term roofline (task-spec numbers)."""

    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    peak_flops_fp8: float = 394e12
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_link_bw: float = 50e9  # bytes/s per link per direction
    ici_links: int = 4  # 2D torus
    hbm_capacity: float = 16 * 1024**3  # bytes
    vmem_capacity: float = 128 * 1024**2  # bytes


ROOFLINE_TARGET = RooflineTarget()


def roofline_target_for(spec: TPUSpec) -> RooflineTarget:
    """Per-chip ``RooflineTarget`` built from a Table-1 generation spec.

    Lets the three-term roofline (``core.roofline``) model *any*
    generation, not just the repo's v5e dry-run target — the fleet
    simulator's roofline-fed step times (``fleet.perf``) price every
    generation's step time from its own Table-1 column. ``peak_flops``
    stays bf16 (training normalization); FP8 peak rides along for parts
    that support it."""
    return RooflineTarget(
        name=spec.name,
        peak_flops=spec.peak_bf16_tflops * 1e12,
        peak_flops_fp8=(spec.peak_fp8_tflops or spec.peak_bf16_tflops)
        * 1e12,
        hbm_bw=spec.hbm_gbps * 1e9,
        ici_link_bw=spec.ici_link_gbps * 1e9,
        ici_links=spec.ici_links,
        hbm_capacity=spec.hbm_gib * 1024**3,
        vmem_capacity=spec.vmem_mib * 1024**2,
    )
