"""Goodput accounting, as defined by the paper.

    "Goodput is short for 'good throughput', which in training systems is the
    rate of good or effective training progress. For example, we might report
    a training throughput of X for a system in normal operation, but if the
    system spends 10% of its total time recovering from errors or failures,
    then the goodput would be 0.9X."

The ledger tracks wall time partitioned into productive step time, wasted
rework (steps lost since the last checkpoint), failure detection time, and
restart/restore overhead. It is fed by the trainer (real measured intervals)
or by the resilience simulator (modeled intervals) — both report
``goodput = productive / total``, comparable to the paper's Gemini numbers
(97% on TPU v4 [Gemini23], 93% multi-pod on TPU v5p [Gemini25]).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass
class GoodputEvent:
    kind: str  # "steps" | "rework" | "detect" | "restore" | "idle"
    seconds: float
    steps: int = 0
    note: str = ""


@dataclasses.dataclass
class GoodputLedger:
    events: List[GoodputEvent] = dataclasses.field(default_factory=list)

    # -- recording -----------------------------------------------------------

    def record_steps(self, seconds: float, steps: int, note: str = "") -> None:
        self._record("steps", seconds, steps, note)

    def record_rework(self, seconds: float, steps: int, note: str = "") -> None:
        """Steps re-executed after restore (lost progress since checkpoint)."""
        self._record("rework", seconds, steps, note)

    def record_detection(self, seconds: float, note: str = "") -> None:
        self._record("detect", seconds, 0, note)

    def record_restore(self, seconds: float, note: str = "") -> None:
        self._record("restore", seconds, 0, note)

    def record_idle(self, seconds: float, note: str = "") -> None:
        self._record("idle", seconds, 0, note)

    def _record(self, kind: str, seconds: float, steps: int, note: str) -> None:
        if seconds < 0:
            raise ValueError("negative duration")
        self.events.append(GoodputEvent(kind, seconds, steps, note))

    # -- reporting -----------------------------------------------------------

    def totals(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0.0) + e.seconds
        return out

    @property
    def total_seconds(self) -> float:
        return sum(e.seconds for e in self.events)

    @property
    def productive_seconds(self) -> float:
        return sum(e.seconds for e in self.events if e.kind == "steps")

    @property
    def goodput(self) -> float:
        tot = self.total_seconds
        return self.productive_seconds / tot if tot > 0 else 1.0

    @property
    def effective_steps(self) -> int:
        return sum(e.steps for e in self.events if e.kind == "steps")

    def structure(self) -> List[Tuple[str, int]]:
        """The ledger as a (kind, steps) sequence with consecutive
        same-kind events merged.

        Durations are dropped: a *measured* ledger (ResilientTrainer) and
        a *modeled* one (fleet simulator) driven by the same failure plan
        must agree on this sequence event-for-event even though their
        seconds differ — the fleet bridge pins exactly that."""
        out: List[Tuple[str, int]] = []
        for e in self.events:
            if out and out[-1][0] == e.kind:
                out[-1] = (e.kind, out[-1][1] + e.steps)
            else:
                out.append((e.kind, e.steps))
        return out

    def summary(self) -> Dict[str, float]:
        t = self.totals()
        return {
            "goodput": self.goodput,
            "total_s": self.total_seconds,
            "productive_s": t.get("steps", 0.0),
            "rework_s": t.get("rework", 0.0),
            "detect_s": t.get("detect", 0.0),
            "restore_s": t.get("restore", 0.0),
            "idle_s": t.get("idle", 0.0),
            "effective_steps": float(self.effective_steps),
        }


def modeled_goodput(
    *,
    mtbf_hours: float,
    detect_s: float,
    restore_s: float,
    checkpoint_interval_s: float,
    checkpoint_write_s: float = 0.0,
) -> float:
    """Closed-form expected goodput for a synchronous job.

    Per failure (rate lambda = 1/MTBF) we lose: detection + restore + on
    average half a checkpoint interval of rework. Checkpoint writes that
    block training cost checkpoint_write_s per interval (0 if async).
    """
    lam = 1.0 / (mtbf_hours * 3600.0)
    loss_per_failure = detect_s + restore_s + 0.5 * checkpoint_interval_s
    overhead = lam * loss_per_failure + checkpoint_write_s / checkpoint_interval_s
    return 1.0 / (1.0 + overhead)
