"""Torus / cube topology math for TPU pods.

The paper's interconnect story: ICI links form a 2D torus (TPU v2/v3) or a
3D torus (TPU v4+), physically built (since v4) from electrically-cabled
4x4x4 "cubes" whose 96 face links terminate on optical circuit switches
(OCSes). Opposing faces of the torus connect through the same OCS, so the
scheduler can stitch any set of cubes into a torus and map failed cubes out.

This module provides the pure geometry: torus shapes, neighbor maps,
bisection bandwidth, cube decomposition, and collective cost models
(ring/bidirectional-torus all-reduce and all-to-all hop counts) used by the
roofline's collective term and the OCS scheduler.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, List, Sequence, Tuple

Coord = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Torus:
    """An N-dimensional torus of nodes with per-direction link bandwidth."""

    dims: Tuple[int, ...]
    link_gbps: float  # per direction, paper footnote 4

    @property
    def num_nodes(self) -> int:
        return math.prod(self.dims)

    @property
    def links_per_node(self) -> int:
        """External ICI links per node: 2 per torus dimension, except
        dimensions of size 1 (no links) and size 2 (single wraparound)."""
        n = 0
        for d in self.dims:
            if d >= 3:
                n += 2
            elif d == 2:
                n += 1
        return n

    def bisection_gbps(self) -> float:
        """Bisection bandwidth across the longest dimension (paper Table 1):
        2 * (num_nodes / longest) links, each link_gbps per direction."""
        longest = max(self.dims)
        if longest < 2:
            return 0.0
        cross = self.num_nodes // longest
        wrap = 2 if longest >= 3 else 1
        return wrap * cross * self.link_gbps

    def neighbors(self, coord: Coord) -> List[Coord]:
        out = []
        for axis, size in enumerate(self.dims):
            if size < 2:
                continue
            for step in (-1, +1):
                nxt = list(coord)
                nxt[axis] = (coord[axis] + step) % size
                if tuple(nxt) != coord:
                    out.append(tuple(nxt))
        # dedupe (size-2 dims produce the same neighbor twice)
        seen, uniq = set(), []
        for c in out:
            if c not in seen:
                seen.add(c)
                uniq.append(c)
        return uniq

    def all_coords(self) -> Iterable[Coord]:
        return itertools.product(*(range(d) for d in self.dims))

    # ----- collective cost models (used by the roofline collective term) ---

    def ring_allreduce_time(self, bytes_per_node: float, axis: int) -> float:
        """Bandwidth-optimal ring all-reduce along one torus axis.

        Moves 2*(n-1)/n * bytes per node through each link; a torus ring is
        bidirectional so effective bandwidth is 2*link (one ring each way).
        Returns seconds.
        """
        n = self.dims[axis]
        if n <= 1:
            return 0.0
        bw = 2.0 * self.link_gbps * 1e9
        return (2.0 * (n - 1) / n) * bytes_per_node / bw

    def allgather_time(self, bytes_per_node_out: float, axis: int) -> float:
        """Ring all-gather of a result totalling bytes_per_node_out per node:
        each node receives (n-1)/n of the full output over 2 directions."""
        n = self.dims[axis]
        if n <= 1:
            return 0.0
        bw = 2.0 * self.link_gbps * 1e9
        return ((n - 1) / n) * bytes_per_node_out / bw

    def alltoall_time(self, bytes_per_node: float, axis: int) -> float:
        """All-to-all along one axis: each node sends (n-1)/n of its data;
        average hop distance on a bidirectional ring is ~n/4, giving
        effective per-node throughput 4*link/n ... we use the standard
        torus all-to-all bound: time = bytes * (n/4) / (n * link * 2)."""
        n = self.dims[axis]
        if n <= 1:
            return 0.0
        bw = 2.0 * self.link_gbps * 1e9
        avg_hops = n / 4.0
        return bytes_per_node * ((n - 1) / n) * avg_hops / bw


@dataclasses.dataclass(frozen=True)
class CubeGeometry:
    """TPU v4+ physical building block: a 4x4x4 electrically-cabled cube.

    Each face of the cube exposes 4x4 = 16 ICI links; 6 faces -> 96 optical
    links per cube. Opposing faces must land on the same OCS for torus
    wraparound, so each cube attaches to 6*16/2 = 48 OCSes (paper, Fig. 4).
    """

    side: int = 4

    @property
    def chips(self) -> int:
        return self.side**3

    @property
    def links_per_face(self) -> int:
        return self.side * self.side

    @property
    def optical_links(self) -> int:
        return 6 * self.links_per_face

    @property
    def ocses_per_cube(self) -> int:
        return 6 * self.links_per_face // 2

    def cubes_for(self, num_chips: int) -> int:
        return -(-num_chips // self.chips)  # ceil div


CUBE = CubeGeometry()


def cube_grid(slice_chips: int, cube: CubeGeometry = CUBE) -> Tuple[int, int, int]:
    """Shape (in cubes) of a torus slice of ``slice_chips`` chips.

    Slices are multiples of 64 chips (one cube). We pick the most balanced
    3D arrangement of cubes, matching how slices are carved in practice
    (e.g. 2048 chips = 32 cubes -> 4x4x2 cubes -> 16x16x8 chip torus).
    """
    n = cube.cubes_for(slice_chips)
    best: Tuple[int, int, int] = (n, 1, 1)
    best_score = float("inf")
    for a in range(1, n + 1):
        if n % a:
            continue
        for b in range(1, n // a + 1):
            if (n // a) % b:
                continue
            c = n // a // b
            dims = tuple(sorted((a, b, c)))
            score = max(dims) / min(dims)
            if score < best_score:
                best_score = score
                best = dims  # type: ignore[assignment]
    return best  # cubes per axis


def slice_torus(slice_chips: int, link_gbps: float,
                cube: CubeGeometry = CUBE) -> Torus:
    """Chip-level torus for a slice assembled from cubes via OCS."""
    ca, cb, cc = cube_grid(slice_chips, cube)
    return Torus(dims=(ca * cube.side, cb * cube.side, cc * cube.side),
                 link_gbps=link_gbps)


def mesh_axis_torus(mesh_shape: Sequence[int], axis_names: Sequence[str],
                    link_gbps: float) -> Dict[str, Torus]:
    """Map logical mesh axes onto torus rings for collective costing.

    For the production meshes in this repo:
      (16,16)      -> data and model each ride one 16-ring of the 2D torus.
      (2,16,16)    -> pod axis crosses the inter-pod DCN/ICI boundary; data
                      and model ride intra-pod rings.
    Each axis is modeled as a 1-D (ring) torus of its own size sharing the
    per-direction ICI link bandwidth. The "pod" axis gets the same link rate
    (paper: cross-pod synchronous DP is feasible at >90% goodput; we model
    its bandwidth as ICI-class and note the assumption in DESIGN.md).
    """
    return {
        name: Torus(dims=(size,), link_gbps=link_gbps)
        for name, size in zip(axis_names, mesh_shape)
    }
