"""Three-term roofline model from compiled dry-run artifacts.

Terms, all in seconds per step (per-device program; since SPMD compiles one
partition's program, per-device FLOPs/bytes already embody the /chips of the
task formula):

  compute    = dot_FLOPs_per_device / peak_FLOP/s
  memory     = HBM_bytes_per_device / HBM_bw
  collective = sum over collectives of a ring-model time on the mesh axis
               the collective spans (parsed from replica_groups)

Hardware constants: TPU v5e target (197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI per direction). Torus rings are bidirectional, so ring
collectives see 2x link bandwidth. The cross-pod "pod" axis is modeled at
DCN-class bandwidth (configurable; default 1/4 ICI) — the paper's multi-pod
Gemini training rides data-parallel all-reduce across data centers.

The report also carries MODEL_FLOPS (6*N*D train / 2*N*D inference, dense;
active params for MoE) so we can report the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, which catches remat and redundancy waste.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hlo_analysis import CollectiveRecord, HloCostReport
from repro.core.hwspec import ROOFLINE_TARGET, RooflineTarget


@dataclasses.dataclass(frozen=True)
class AxisLink:
    size: int
    link_bw: float  # bytes/s per direction


def mesh_axis_links(mesh_shape: Sequence[int], axis_names: Sequence[str],
                    target: RooflineTarget = ROOFLINE_TARGET,
                    pod_bw_fraction: float = 0.25) -> Dict[str, AxisLink]:
    links = {}
    for name, size in zip(axis_names, mesh_shape):
        bw = target.ici_link_bw
        if name == "pod":
            bw *= pod_bw_fraction  # cross-datacenter DCN-class
        links[name] = AxisLink(size=size, link_bw=bw)
    return links


def collective_time(rec: CollectiveRecord,
                    links: Dict[str, AxisLink]) -> float:
    """Ring-model time for one collective (single execution)."""
    axes = [a for a in rec.axes if a in links]
    if not axes:
        # unknown grouping: conservative — slowest link, full group size
        link = min(links.values(), key=lambda l: l.link_bw)
        n = rec.group_size
    else:
        link = min((links[a] for a in axes), key=lambda l: l.link_bw)
        n = rec.group_size
    if n <= 1:
        return 0.0
    bw = 2.0 * link.link_bw  # bidirectional ring
    op = rec.opcode
    if op in ("all-reduce",):
        return 2.0 * (n - 1) / n * rec.result_bytes / bw
    if op in ("all-gather",):
        return (n - 1) / n * rec.result_bytes / bw
    if op in ("reduce-scatter",):
        return (n - 1) / n * rec.operand_bytes / bw
    if op in ("all-to-all", "ragged-all-to-all"):
        avg_hops = n / 4.0
        return (n - 1) / n * avg_hops * rec.result_bytes / bw
    if op in ("collective-permute",):
        return rec.result_bytes / link.link_bw
    return rec.result_bytes / bw


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh_desc: str
    chips: int
    # raw inputs
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    peak_memory_bytes: float
    model_flops_global: float
    # terms (seconds/step)
    t_compute: float
    t_memory: float
    t_collective: float
    collective_by_axes: Dict[Tuple[str, ...], float]
    hbm_capacity: float
    peak_flops: float = 197e12
    notes: str = ""

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def t_bound(self) -> float:
        """Step-time lower bound assuming perfect overlap of the 3 engines
        (MXU, HBM, ICI) — the roofline."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        """No-overlap upper bound."""
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global). >1 would mean undercounted HLO;
        <1 means remat/redundant compute."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the pod's peak FLOP/s devoted to *useful* model FLOPs
        at the roofline step time — the score we hillclimb.

        = (MODEL_FLOPS / chips / peak) / t_bound
        """
        if self.t_bound <= 0:
            return 0.0
        t_useful = self.model_flops_global / self.chips / self.peak_flops
        return t_useful / self.t_bound

    @property
    def fits_hbm(self) -> bool:
        return self.peak_memory_bytes <= self.hbm_capacity

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh_desc,
            "chips": self.chips,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bound": self.bound,
            "t_bound_s": round(self.t_bound, 6),
            "model_flops": f"{self.model_flops_global:.3e}",
            "useful_ratio": round(self.useful_flops_ratio, 3),
            "roofline_frac": round(self.roofline_fraction, 4),
            "mem_gib_per_chip": round(self.peak_memory_bytes / 2**30, 2),
            "fits_hbm": self.fits_hbm,
            "notes": self.notes,
        }


def build_report(
    *,
    arch: str,
    shape: str,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    cost: HloCostReport,
    model_flops_global: float,
    target: RooflineTarget = ROOFLINE_TARGET,
    pod_bw_fraction: float = 0.25,
    notes: str = "",
    peak_flops: Optional[float] = None,
) -> RooflineReport:
    chips = math.prod(mesh_shape)
    peak = peak_flops or target.peak_flops
    links = mesh_axis_links(mesh_shape, axis_names, target, pod_bw_fraction)
    t_coll = sum(collective_time(c, links) * c.multiplier
                 for c in cost.collectives)
    by_axes: Dict[Tuple[str, ...], float] = {}
    for c in cost.collectives:
        by_axes[c.axes] = by_axes.get(c.axes, 0.0) + c.total_operand_bytes
    return RooflineReport(
        arch=arch, shape=shape,
        mesh_desc="x".join(str(s) for s in mesh_shape),
        chips=chips,
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.hbm_bytes,
        collective_bytes_per_device=cost.collective_bytes(),
        peak_memory_bytes=cost.peak_memory_bytes,
        model_flops_global=model_flops_global,
        t_compute=cost.flops / peak,
        t_memory=cost.hbm_bytes / target.hbm_bw,
        t_collective=t_coll,
        collective_by_axes=by_axes,
        hbm_capacity=target.hbm_capacity,
        peak_flops=peak,
        notes=notes,
    )


def model_flops(n_params_active: float, tokens: float,
                training: bool) -> float:
    """The paper-standard napkin: 6*N*D for a training step (fwd+bwd),
    2*N*D forward-only (prefill/decode)."""
    return (6.0 if training else 2.0) * n_params_active * tokens


def synthetic_train_cost(
    *,
    n_params_active: float,
    tokens_global: float,
    chips: int,
    param_bytes: float = 2.0,
    grad_bytes: float = 4.0,
    traversals: float = 3.0,
    opt_state_bytes: float = 8.0,
) -> HloCostReport:
    """First-order ``HloCostReport`` for an FSDP data-parallel training
    step, for callers with no compiled dry-run artifact (the fleet's
    roofline-fed step times, ``fleet.perf``).

    Per device, per step: FLOPs are the 6*N*T napkin split across chips;
    HBM traffic streams the *gathered* params once per traversal (fwd,
    remat-fwd, bwd — FSDP re-gathers shards each time, so this term does
    not shrink with scale) plus gradient and optimizer-state read/write
    on the shard; the collective is the ring grad all-reduce over the
    data axis. Deliberately omits activation traffic (model-shape
    dependent) — see ``core.napkin`` for the shape-aware model."""
    if chips <= 0:
        raise ValueError("chips must be positive")
    flops = 6.0 * n_params_active * tokens_global / chips
    hbm = traversals * n_params_active * param_bytes \
        + n_params_active / chips * (2.0 * grad_bytes
                                     + 2.0 * opt_state_bytes)
    collectives: List[CollectiveRecord] = []
    if chips > 1:
        grad_all_reduce_bytes = n_params_active * grad_bytes
        collectives.append(CollectiveRecord(
            opcode="all-reduce", comp="synthetic",
            result_bytes=grad_all_reduce_bytes,
            operand_bytes=grad_all_reduce_bytes,
            group_size=chips, groups=(), multiplier=1.0,
            axes=("data",)))
    peak_mem = n_params_active / chips * (param_bytes + opt_state_bytes
                                          + grad_bytes)
    return HloCostReport(flops=flops, hbm_bytes=hbm,
                         collectives=collectives,
                         peak_memory_bytes=peak_mem)
