"""Silent-data-corruption defenses: FBIST analogue + sampled replay checker.

Paper (§Resilience, Ironwood): two hardware mechanisms combat SDC —

  1. FBIST — a functional built-in self-test engine inside the MXU runs
     high-coverage test patterns at burn-in and during operation to catch
     marginal silicon;
  2. hardware replay — the VPU opportunistically re-executes randomly
     sampled vector bundles on idle lanes ("replaying odd-lane operations on
     the even lanes") and compares, with zero architectural state change.

We implement the *policies* at framework level with the same detection
semantics. FBIST runs golden test patterns through the very kernels used for
training and compares against precomputed checksums; the replay checker
re-executes a sampled fraction of a step's vector work on permuted lanes and
demands bitwise equality (TPU/XLA vector ops are deterministic, so any
mismatch is corruption). Faults are injected in tests via ``FaultyDevice``.
Detected devices are reported to the resilience layer, which maps them out
via the OCS scheduler — completing the paper's detect -> map-out loop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Fault injection (tests / simulation only).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultModel:
    """A marginal-silicon fault: with probability ``rate`` per call, flip a
    low-order mantissa bit region of one output element (classic SDC: a
    plausible-looking wrong value, not a NaN)."""

    rate: float = 1.0
    magnitude: float = 1e-2
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def corrupt(self, x: np.ndarray) -> np.ndarray:
        if self._rng.random() >= self.rate or x.size == 0:
            return x
        x = np.array(x, copy=True)
        idx = self._rng.integers(0, x.size)
        flat = x.reshape(-1)
        flat[idx] = flat[idx] * (1.0 + self.magnitude) + self.magnitude
        return x


def faulty_wrap(fn: Callable[..., Array],
                fault: FaultModel) -> Callable[..., Array]:
    """Wrap a compute callable so its output is silently corrupted."""

    def wrapped(*args: Array) -> Array:
        out = np.asarray(fn(*args))
        return jnp.asarray(fault.corrupt(out))

    return wrapped


# ---------------------------------------------------------------------------
# FBIST: functional built-in self test for matmul units.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FBISTReport:
    passed: bool
    patterns_run: int
    first_failing_pattern: Optional[int]
    max_abs_err: float


class FBIST:
    """Golden-pattern self-test for a matmul implementation.

    Patterns are chosen for datapath coverage the way hardware FBIST
    patterns are: dense random (exercise all PEs), rank-1 structured
    (systolic edge propagation), alternating-sign checkerboards (carry
    chains), denormal-adjacent small values, and large-magnitude values
    (accumulator range). Goldens come from float64 numpy — an independent
    oracle of the unit under test.
    """

    def __init__(self, m: int = 128, k: int = 128, n: int = 128,
                 n_patterns: int = 8, seed: int = 1234,
                 tol: float = 5e-2):
        self.shape = (m, k, n)
        self.n_patterns = n_patterns
        self.seed = seed
        self.tol = tol
        self._patterns = [self._make_pattern(i) for i in range(n_patterns)]

    def _make_pattern(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        m, k, n = self.shape
        rng = np.random.default_rng(self.seed + i)
        kind = i % 5
        if kind == 0:  # dense random
            a = rng.standard_normal((m, k))
            b = rng.standard_normal((k, n))
        elif kind == 1:  # rank-1 structured
            a = np.outer(rng.standard_normal(m), np.ones(k))
            b = np.outer(np.ones(k), rng.standard_normal(n))
        elif kind == 2:  # checkerboard
            a = ((np.indices((m, k)).sum(0) % 2) * 2.0 - 1.0)
            b = ((np.indices((k, n)).sum(0) % 2) * 2.0 - 1.0)
        elif kind == 3:  # tiny magnitudes
            a = rng.standard_normal((m, k)) * 1e-3
            b = rng.standard_normal((k, n)) * 1e-3
        else:  # large magnitudes (accumulator range)
            a = rng.standard_normal((m, k)) * 64.0
            b = rng.standard_normal((k, n)) * 64.0
        return a.astype(np.float32), b.astype(np.float32)

    def run(self, matmul: Callable[[Array, Array], Array]) -> FBISTReport:
        max_err = 0.0
        for i, (a, b) in enumerate(self._patterns):
            golden = a.astype(np.float64) @ b.astype(np.float64)
            got = np.asarray(matmul(jnp.asarray(a), jnp.asarray(b)),
                             dtype=np.float64)
            scale = np.maximum(np.abs(golden), 1.0)
            err = float(np.max(np.abs(got - golden) / scale))
            max_err = max(max_err, err)
            if not np.isfinite(err) or err > self.tol:
                return FBISTReport(False, i + 1, i, max_err)
        return FBISTReport(True, self.n_patterns, None, max_err)


# ---------------------------------------------------------------------------
# Replay checker: sampled redundant execution of vector work.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplayReport:
    passed: bool
    bundles_checked: int
    mismatches: int


class ReplayChecker:
    """Sampled redundant execution with lane permutation.

    ``check(fn, x, key)`` picks a random ``sample_frac`` of rows ("bundles")
    of x, evaluates fn on them twice — once as-is and once with the lane
    (last) dimension reversed, un-reversing the result — and requires exact
    equality for elementwise fn. The reversal means the redundant pass uses
    different physical lanes, which is what catches a bad lane (the paper's
    odd-lanes-on-even-lanes trick). Zero impact on the training step itself:
    it is a separate, sampled computation.
    """

    def __init__(self, sample_frac: float = 0.0625, atol: float = 0.0):
        if not 0.0 < sample_frac <= 1.0:
            raise ValueError("sample_frac in (0, 1]")
        self.sample_frac = sample_frac
        self.atol = atol

    def check(self, fn: Callable[[Array], Array], x: Array,
              key: Array) -> ReplayReport:
        if x.ndim < 2:
            x = x.reshape(1, -1)
        n = x.shape[0]
        k = max(1, int(round(n * self.sample_frac)))
        idx = jax.random.choice(key, n, (k,), replace=False)
        sample = jnp.take(x, idx, axis=0)
        primary = fn(sample)
        replayed = jnp.flip(fn(jnp.flip(sample, axis=-1)), axis=-1)
        diff = np.asarray(jnp.abs(primary - replayed))
        mismatches = int((diff > self.atol).sum())
        return ReplayReport(mismatches == 0, k, mismatches)


# ---------------------------------------------------------------------------
# Fleet-level SDC rate model (consumed by the fleet simulator).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SDCRateModel:
    """Occurrence + detection statistics for silent data corruption.

    Corruptions arrive as a Poisson process at ``rate_per_chip_hour`` per
    chip. Detection is by the sampled screens above (FBIST patterns /
    replay checks) run every ``screen_interval_s``; each screen catches an
    active corruption with probability ``screen_coverage``, so the
    detection delay is geometric over screen intervals. The killer
    property the simulator reproduces: unlike fail-stop failures, the
    rework after an SDC reaches back to the last checkpoint *before the
    corruption occurred* — every checkpoint written while the corruption
    went undetected is poisoned.
    """

    rate_per_chip_hour: float = 1e-7
    screen_interval_s: float = 300.0
    screen_coverage: float = 0.9

    def __post_init__(self) -> None:
        if not 0.0 < self.screen_coverage <= 1.0:
            raise ValueError("screen_coverage in (0, 1]")

    def corruption_rate_per_s(self, chips: int) -> float:
        return self.rate_per_chip_hour * chips / 3600.0

    def draw_time_to_corruption_s(self, rng: np.random.Generator,
                                  chips: int) -> float:
        rate = self.corruption_rate_per_s(chips)
        if rate <= 0.0:
            return float("inf")
        return float(rng.exponential(1.0 / rate))

    def draw_detection_delay_s(self, rng: np.random.Generator) -> float:
        """Time from corruption to a screen catching it (geometric over
        screens; the first opportunity is the next screen boundary)."""
        missed = int(rng.geometric(self.screen_coverage)) - 1
        offset = float(rng.uniform(0.0, self.screen_interval_s))
        return offset + missed * self.screen_interval_s


# ---------------------------------------------------------------------------
# Fleet screening loop (FBIST across devices; OCS map-out hook).
# ---------------------------------------------------------------------------


def screen_devices(
    matmuls: Sequence[Callable[[Array, Array], Array]],
    *,
    fbist: Optional[FBIST] = None,
) -> List[int]:
    """Run FBIST across a fleet of per-device matmul callables; return the
    indices of defective devices (to be mapped out via the OCS scheduler)."""
    fb = fbist or FBIST()
    bad = []
    for i, mm in enumerate(matmuls):
        if not fb.run(mm).passed:
            bad.append(i)
    return bad
