"""Analytic (napkin) per-cell cost model — the TPU-expected numbers.

The dry-run's HLO-derived terms measure the program XLA:CPU compiled, which
differs from the TPU program in two systematic ways: (a) XLA:CPU upcasts
bf16 dot operands to f32 (2x bytes on every weight/activation it touches),
and (b) jax accumulates scan-constant cotangents in f32. The roofline
report therefore carries BOTH the as-compiled terms and this analytic
model, which is also the basis for the hypothesis->change->measure loop in
EXPERIMENTS.md §Perf (every optimization's predicted win is computed from
these formulas first).

Model (per device, per step, bytes):
  train:   3 traversals (fwd, remat-fwd, bwd) x sharded param bytes
           + 2 x saved layer inputs (write + read)   [remat checkpoints]
           + attention score traffic (xla impl materializes fp32 scores;
             the Pallas flash kernel makes this term vanish)
           + grads + optimizer state read/write
  prefill: 1 traversal x params + score traffic + KV cache write
  decode:  1 traversal x params (weights are read once per token!)
           + KV cache read (the long-context wall) + state r/w
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from repro.configs.registry import Cell, CellSettings, ShapeSpec
from repro.core.hwspec import ROOFLINE_TARGET, RooflineTarget
from repro.models.config import ModelConfig

DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "int8": 1, "float8_e4m3fn": 1}


@dataclasses.dataclass(frozen=True)
class NapkinReport:
    t_compute: float
    t_memory: float
    t_collective: float
    detail: Dict[str, float]

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)


def _mesh_sizes(mesh_shape: Tuple[int, ...], axis_names: Tuple[str, ...]
                ) -> Dict[str, int]:
    return dict(zip(axis_names, mesh_shape))


def analyze_cell(cell: Cell, mesh_shape: Tuple[int, ...],
                 axis_names: Tuple[str, ...],
                 target: RooflineTarget = ROOFLINE_TARGET,
                 *, flash_attention: bool = False,
                 pod_bw_fraction: float = 0.25) -> NapkinReport:
    cfg = cell.config
    s = cell.settings
    shape = cell.shape
    sizes = _mesh_sizes(mesh_shape, axis_names)
    chips = math.prod(mesh_shape)
    model_par = sizes.get("model", 1)
    data_par = sizes.get("data", 1) * sizes.get("pod", 1)

    p_bytes_total = cfg.total_params() * DTYPE_BYTES[s.param_dtype]
    # TP shards the big dims ~evenly; FSDP rules also shard experts over
    # data(+pod). Approximate the per-device resident fraction:
    moe_layers = sum(cfg.sublayer_has_moe(i)
                     for i in range(cfg.block_len)) * cfg.n_blocks \
        if cfg.n_experts else 0
    expert_params = cfg.n_experts * cfg.expert_mlp_params() * moe_layers
    if s.rules == "fsdp_tp_sp" and cfg.n_experts:
        expert_frac = expert_params / cfg.total_params()
        shard = expert_frac / (model_par * data_par) + \
            (1 - expert_frac) / model_par
    else:
        shard = 1.0 / model_par
    p_dev = p_bytes_total * shard

    tokens_global = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1)
    tokens_dev = tokens_global / data_par
    act_bytes = 2  # bf16 activations
    d = cfg.d_model

    # attention score traffic per traversal (xla impl, fp32 scores, both
    # written and read around the softmax)
    kinds = cfg.sublayer_kinds()
    n_attn = sum(k == "attn" for k in kinds) * cfg.n_blocks
    if cfg.is_encoder_decoder:
        n_attn = cfg.n_layers * 2 + cfg.encoder_layers
    heads_dev = max(cfg.n_heads / model_par, 1) if cfg.n_heads else 0
    if shape.kind in ("train", "prefill") and n_attn and not flash_attention:
        kv_len = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        per_layer_scores = (shape.global_batch / data_par) * heads_dev \
            * shape.seq_len * kv_len * 4 * 2  # write+read fp32
        score_traffic = per_layer_scores * n_attn
    else:
        score_traffic = 0.0

    flops_dev = 0.0
    mem = 0.0
    coll_bytes_model = 0.0  # bytes reduced over the model axis
    coll_bytes_data = 0.0

    active_p = cfg.active_params()
    if shape.kind == "train":
        flops_dev = 6.0 * active_p * tokens_global / chips
        traversals = 3.0  # fwd + remat fwd + bwd
        mem += traversals * p_dev
        # saved layer inputs: one (B_mb, S, D) per layer per microbatch,
        # written then read; sequence-parallel when fsdp rules
        sp = model_par if s.rules == "fsdp_tp_sp" else 1
        saved = (cfg.n_layers * tokens_dev * d * act_bytes / sp) * 2
        mem += saved
        mem += score_traffic * 1.5  # fwd + recompute (bwd reads recomputed)
        accum_b = DTYPE_BYTES[s.accum_dtype]
        mem += cfg.total_params() * shard * accum_b * 2  # grad write+read
        mem += p_dev * 2  # optimizer state r/w (adafactor ~ params bf16-ish)
        # gradient all-reduce over data axis for non-expert params
        # (expert grads stay expert-sharded)
        dense_p = cfg.total_params() - expert_params
        gb = dense_p / model_par * accum_b
        coll_bytes_data += 2.0 * gb  # ring all-reduce ~2x
        # TP activation collectives: ~4 all-reduces of (tokens, d) per
        # layer across fwd+bwd, ring factor ~2
        coll_bytes_model += 4 * cfg.n_layers * tokens_dev * d * act_bytes * 2
    elif shape.kind == "prefill":
        flops_dev = 2.0 * active_p * tokens_global / chips
        mem += p_dev
        mem += score_traffic
        kv_len = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        kvb = DTYPE_BYTES[s.cache_dtype]
        n_kv_layers = n_attn
        mem += (shape.global_batch / data_par) * n_kv_layers * kv_len * \
            cfg.n_kv_heads * cfg.resolved_head_dim * 2 * kvb
        coll_bytes_model += 2 * cfg.n_layers * tokens_dev * d * act_bytes * 2
    else:  # decode
        flops_dev = 2.0 * active_p * tokens_global / chips
        mem += p_dev  # every weight read once per token
        kv_len = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        kvb = DTYPE_BYTES[s.cache_dtype]
        kv_layers = (sum(k == "attn" for k in kinds) * cfg.n_blocks
                     if not cfg.is_encoder_decoder else cfg.n_layers)
        kv_total = (shape.global_batch * kv_layers * kv_len *
                    cfg.n_kv_heads * cfg.resolved_head_dim * 2 * kvb)
        mem += kv_total / chips  # cache sharded over batch x kv_seq
        # recurrent states (mamba/rwkv)
        n_ssm = sum(k in ("mamba", "rwkv") for k in kinds) * cfg.n_blocks
        if n_ssm:
            if cfg.default_kind == "rwkv":
                state = cfg.rwkv_heads * cfg.rwkv_head_dim ** 2 * 4
            else:
                state = cfg.d_inner * cfg.ssm_state_dim * 4
            mem += shape.global_batch * n_ssm * state * 2 / data_par
        coll_bytes_model += 2 * cfg.n_layers * tokens_dev * d * act_bytes * 2

    links_model = 2.0 * target.ici_link_bw
    links_data = 2.0 * target.ici_link_bw
    t_coll = coll_bytes_model / links_model + coll_bytes_data / links_data

    return NapkinReport(
        t_compute=flops_dev / target.peak_flops,
        t_memory=mem / target.hbm_bw,
        t_collective=t_coll,
        detail={
            "params_dev_gib": p_dev / 2**30,
            "score_traffic_gib": score_traffic / 2**30,
            "mem_gib": mem / 2**30,
            "flops_dev": flops_dev,
            "coll_model_gib": coll_bytes_model / 2**30,
            "coll_data_gib": coll_bytes_data / 2**30,
        })
