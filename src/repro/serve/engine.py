"""Continuous-batching serving engine with a device-resident decode loop.

The Ironwood-era premise: serving is a first-class supercomputer workload,
so the engine is built like one —

  * **Continuous batching** (scheduler.py): requests are admitted into
    free batch slots and drained *mid-decode*; finished or preempted
    slots refill without flushing the batch.
  * **Block/paged KV cache** (kv_cache.py): pure-attention stacks store
    KV in a shared page pool addressed through a device page table, with
    int8 page quantization as the HBM lever; other families (Mamba/RWKV/
    enc-dec) use per-slot dense ring/state caches behind the same
    interface.
  * **Device-resident decode** : the hot loop is a ``lax.scan`` of
    ``chunk`` decode steps compiled once — sample, EOS/budget masking,
    cache write and position bookkeeping all stay on device. The host
    syncs once per *chunk* (not per token) to drain emitted tokens and
    make scheduling decisions.

The legacy single-batch ``generate()`` survives as a thin wrapper that
submits one request per batch row; ``generate_pertoken()`` keeps the old
one-jit-call-per-token loop as the benchmark baseline.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig
from repro.serve.kv_cache import DenseKVCache, PagedKVCache
from repro.serve.scheduler import ContinuousBatchingScheduler, Request

Array = jax.Array
PyTree = Any

PAD_TOKEN = -1  # emitted by finished slots inside a chunk


@dataclasses.dataclass
class ServeEngine:
    """``window``: max total tokens per request (prompt + generated)."""

    cfg: ModelConfig
    ctx: ModelContext
    window: int
    max_batch: int = 4
    chunk: int = 8
    page_size: int = 8
    num_pages: Optional[int] = None
    paged: Optional[bool] = None  # None -> auto by family
    eos_id: Optional[int] = None
    temperature: float = 0.0

    def __post_init__(self) -> None:
        cfg, ctx = self.cfg, self.ctx
        if self.paged is None:
            self.paged = api.supports_paged_decode(cfg)
        if self.paged and not api.supports_paged_decode(cfg):
            raise ValueError(f"{cfg.name}: paged serving unsupported")
        self.counters = {"prefills": 0, "chunks": 0, "decode_steps": 0,
                         "host_syncs": 0, "pertoken_steps": 0,
                         "pages_trimmed": 0}
        if self.paged:
            # +1 page of table headroom: a finished slot's frozen pos can
            # sit exactly at `window`, whose page index must still resolve
            # (to the trash page) instead of clamping into a live page.
            self.pages_per_seq = -(-self.window // self.page_size) + 1
            self.prefill_len = self.pages_per_seq * self.page_size
            if self.num_pages is None:
                self.num_pages = 1 + self.max_batch * self.pages_per_seq
            # prefill computes fp caches at absolute slots (no SWA ring);
            # quantization happens on page write
            self._prefill_ctx = ModelContext(
                compute_dtype=ctx.compute_dtype, q_chunk=ctx.q_chunk,
                shard=ctx.shard, mamba_chunk=ctx.mamba_chunk,
                rwkv_chunk=ctx.rwkv_chunk, attn_impl=ctx.attn_impl,
                full_cache_window=True)
            self.kv: Any = PagedKVCache(
                cfg, ctx, self.num_pages, self.page_size, self.max_batch,
                self.pages_per_seq)
        else:
            self._prefill_ctx = ctx
            self.kv = DenseKVCache(cfg, ctx, self.window, self.max_batch)
        # Pure state-family stacks (mamba/rwkv) carry O(1) state, so the
        # dense prefill would otherwise compile once per prompt length.
        # Front-padding to power-of-two buckets (masked embeddings; the
        # recurrent state stays zero through the pad prefix) bounds the
        # compile count to log2(window).
        self.bucket_prefill = (not self.paged
                               and not cfg.is_encoder_decoder
                               and set(cfg.sublayer_kinds()) <=
                               {"mamba", "rwkv"})
        self.prefill_bucket_sizes: set = set()
        self._build_jitted()
        self._reset_carry()

    # ------------------------------------------------------------ jit build

    @staticmethod
    def _pick(logits: Array, key: Array, temp: Array) -> Array:
        """logits (B,1,V) -> (B,1) int32 next tokens.

        ``temp`` is a traced scalar: greedy (temp <= 0) and sampled paths
        share one compilation, so changing the temperature neither
        recompiles nor requires rebuilding the engine."""
        last = logits[:, -1, :].astype(jnp.float32)
        greedy = jnp.argmax(last, axis=-1)
        sampled = jax.random.categorical(
            key, last / jnp.maximum(temp, 1e-6), axis=-1)
        return jnp.where(temp > 0.0, sampled,
                         greedy)[:, None].astype(jnp.int32)

    @staticmethod
    def _prefill_key(key: Array, rid: int) -> Array:
        """Per-request sampling key for the first token. Double fold (a
        dedicated stream id, then the rid) keeps it disjoint from the
        single-fold per-step chunk keys and from other admissions in the
        same boundary."""
        return jax.random.fold_in(jax.random.fold_in(key, 0x9e3779), rid)

    def _build_jitted(self) -> None:
        cfg, ctx = self.cfg, self.ctx
        eos = self.eos_id

        # ---- prefill ----------------------------------------------------
        def prefill_paged(params, tokens, n_valid, key, temp):
            logits, cache = api.prefill_fn(
                params, {"tokens": tokens}, cfg, self._prefill_ctx,
                window=self.prefill_len, logits_at=n_valid[None] - 1)
            first = self._pick(logits, key, temp)
            return first, cache["blocks"]

        def prefill_dense(params, batch, key, temp):
            logits, cache = api.prefill_fn(params, batch, cfg, ctx,
                                           window=self.window)
            first = self._pick(logits, key, temp)
            return first, cache

        def prefill_bucketed(params, tokens, pad_left, key, temp):
            logits, cache = api.prefill_fn(
                params, {"tokens": tokens}, cfg, ctx, window=self.window,
                pad_left=pad_left)
            first = self._pick(logits, key, temp)
            return first, cache

        self._prefill_paged = jax.jit(prefill_paged)
        self._prefill_dense = jax.jit(prefill_dense)
        self._prefill_bucketed = jax.jit(prefill_bucketed)

        # ---- paged page write -------------------------------------------
        from repro.models.blocks import paged_quantize

        def write_pages(pages, blocks, row):
            m, p = self.pages_per_seq, self.page_size
            new = {}
            for sl, sub in pages.items():
                new[sl] = dict(sub)
                for name in ("k", "v"):
                    dense = blocks[sl][name]  # (L, 1, M*P, KV, D) fp
                    lyr = dense.shape[0]
                    dp = dense.reshape(lyr, m, p, *dense.shape[3:])
                    q, scale = paged_quantize(dp, ctx.cache_dtype)
                    new[sl][name] = sub[name].at[:, row].set(q)
                    if scale is not None:
                        new[sl][name + "_scale"] = \
                            sub[name + "_scale"].at[:, row].set(scale)
            return new

        self._write_pages = jax.jit(write_pages, donate_argnums=(0,))

        # ---- dense slot write -------------------------------------------
        def write_dense(cache, row_cache, slot):
            blocks = jax.tree.map(lambda c, r: c.at[:, slot].set(r[:, 0]),
                                  cache["blocks"], row_cache["blocks"])
            out = dict(cache)
            out["blocks"] = blocks
            return out

        self._write_dense = jax.jit(write_dense, donate_argnums=(0,))

        # ---- device-resident decode chunk -------------------------------
        def chunk_body(params, table, temp, carry, i):
            tok, pos, done, n_out, max_new, key, cache = carry
            if self.paged:
                state = {"pages": cache, "page_table": table, "pos": pos}
                logits, new_state = api.decode_paged_fn(
                    params, tok, state, cfg, ctx)
                new_cache = new_state["pages"]
            else:
                state = dict(cache)
                state["pos"] = pos
                logits, new_state = api.decode_fn(
                    params, tok, state, cfg, ctx)
                new_cache = {k: v for k, v in new_state.items()
                             if k != "pos"}
            emitted = jnp.where(done, PAD_TOKEN, tok[:, 0])
            n_out = n_out + jnp.where(done, 0, 1)
            newly_done = ~done & (n_out >= max_new)
            if eos is not None:
                newly_done |= ~done & (tok[:, 0] == eos)
            done = done | newly_done
            # finished slots freeze: their (garbage) writes keep landing on
            # the same slot/trash page and their position stops advancing
            pos = jnp.where(done, pos, pos + 1)
            nxt = self._pick(logits, jax.random.fold_in(key, i), temp)
            tok = jnp.where(done[:, None], tok, nxt)
            return (tok, pos, done, n_out, max_new, key, new_cache), emitted

        def run_chunk(params, table, tok, pos, done, n_out, max_new, key,
                      temp, t0, cache):
            def step(carry, i):
                return chunk_body(params, table, temp, carry, i)

            carry0 = (tok, pos, done, n_out, max_new, key, cache)
            carry, toks = jax.lax.scan(
                step, carry0, t0 + jnp.arange(self.chunk))
            tok, pos, done, n_out, max_new, _, cache = carry
            return tok, pos, done, n_out, cache, toks.T  # toks (B, C)

        self._run_chunk = jax.jit(run_chunk, donate_argnums=(10,))

    # --------------------------------------------------------- carry state

    def _reset_carry(self) -> None:
        b = self.max_batch
        self._tok = jnp.zeros((b, 1), jnp.int32)
        self._pos = jnp.zeros((b,), jnp.int32)
        self._done = jnp.ones((b,), bool)  # empty slots are "done"
        self._n_out = jnp.zeros((b,), jnp.int32)
        self._max_new = jnp.ones((b,), jnp.int32)
        self._t = 0  # global decode-step clock (also the sampling stream)

    def _admit_into_slot(self, params, req: Request, slot: int,
                         key: Array, temp: Array) -> None:
        rp = req.resume_prompt()
        s = len(rp)
        self.counters["prefills"] += 1
        pkey = self._prefill_key(key, req.rid)
        if self.paged:
            padded = np.full((1, self.prefill_len), 0, np.int32)
            padded[0, :s] = rp
            first, blocks = self._prefill_paged(
                params, jnp.asarray(padded), jnp.int32(s), pkey, temp)
            self.kv.write_prefill(self._write_pages, slot, blocks)
        elif self.bucket_prefill and not req.extras:
            sb = 1 << max(3, (s - 1).bit_length())  # pow2 >= s, floor 8
            self.prefill_bucket_sizes.add(sb)
            padded = np.zeros((1, sb), np.int32)
            padded[0, sb - s:] = rp
            first, cache = self._prefill_bucketed(
                params, jnp.asarray(padded),
                jnp.full((1,), sb - s, jnp.int32), pkey, temp)
            self.kv.write_prefill(self._write_dense, slot, cache)
        else:
            batch = {"tokens": jnp.asarray(rp[None, :])}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)
            first, cache = self._prefill_dense(params, batch, pkey, temp)
            self.kv.write_prefill(self._write_dense, slot, cache)
        self._tok = self._tok.at[slot].set(first[0])
        self._pos = self._pos.at[slot].set(s)
        self._done = self._done.at[slot].set(False)
        self._n_out = self._n_out.at[slot].set(len(req.generated))
        self._max_new = self._max_new.at[slot].set(req.max_new)

    # ---------------------------------------------------------------- run

    def submit_check(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new
        if total > self.window:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"window={self.window}")

    def run(self, params, requests: Sequence[Request], *,
            key: Optional[Array] = None,
            temperature: Optional[float] = None) -> Dict[int, np.ndarray]:
        """Drain all requests; returns {rid: generated tokens}."""
        sched = ContinuousBatchingScheduler(self.max_batch)
        self.scheduler = sched
        key = key if key is not None else jax.random.key(0)
        temp = jnp.float32(self.temperature if temperature is None
                           else temperature)
        for req in requests:
            self.submit_check(req)
            sched.add(req)
        self._reset_carry()
        clock = 0
        while sched.has_work():
            # 1) page headroom for running slots; preempt youngest on
            #    pressure (its pages free up for the older requests)
            if self.paged:
                # grow oldest-first so preemption (youngest-first) never
                # starves the requests with the most progress
                order = sorted(
                    sched.running,
                    key=lambda s: (sched.running[s].arrival,
                                   sched.running[s].rid))
                for slot in order:
                    if slot not in sched.running:
                        continue  # already preempted this boundary
                    req = sched.running[slot]
                    # tokens cached after the next chunk: prompt +
                    # emitted so far + chunk new writes (+1 boundary)
                    target = int(len(req.prompt) + len(req.generated)
                                 + self.chunk + 1)
                    while not self.kv.grow(slot, min(target, self.window)):
                        victim = sched.preempt_victim()
                        if victim is None:
                            raise RuntimeError(
                                "page pool too small for a single request")
                        vslot = victim.slot
                        sched.preempt(victim)
                        self.kv.release(vslot)
                        self._done = self._done.at[vslot].set(True)
                        if vslot == slot:
                            break  # we were the youngest: self-preempted
            # 2) admissions into free slots (never preempt to admit)
            while True:
                req = sched.next_admittable(clock)
                slots = sched.free_slots()
                if req is None or not slots:
                    break
                slot = slots[0]
                if self.paged:
                    need = len(req.resume_prompt()) + self.chunk + 1
                    if not self.kv.grow(slot, min(need, self.window)):
                        break  # no pages: wait for completions
                sched.admit(req, slot)
                self._admit_into_slot(params, req, slot, key, temp)
            if not sched.running:
                if sched.next_admittable(clock) is not None:
                    raise RuntimeError(
                        "admission stalled with an empty batch: the page "
                        "pool cannot hold one request (shrink window or "
                        "grow num_pages)")
                # idle: jump the trace clock to the next arrival
                nxt = min(r.arrival for r in sched.waiting)
                clock = max(clock + self.chunk, nxt)
                continue
            # 3) one device-resident chunk
            sched.record_occupancy(len(sched.running))
            cache = self.kv.pages if self.paged else \
                {k: v for k, v in self.kv.cache.items() if k != "pos"}
            table = self.kv.table_device() if self.paged else jnp.zeros(
                (self.max_batch, 1), jnp.int32)
            (self._tok, self._pos, self._done, self._n_out, new_cache,
             toks) = self._run_chunk(
                params, table, self._tok, self._pos, self._done,
                self._n_out, self._max_new, key, temp,
                jnp.int32(self._t), cache)
            if self.paged:
                self.kv.pages = new_cache
            else:
                new_cache = dict(new_cache)
                new_cache["pos"] = self._pos
                self.kv.update(new_cache)
            self._t += self.chunk
            clock += self.chunk
            self.counters["chunks"] += 1
            self.counters["decode_steps"] += self.chunk
            # 4) drain: the single host sync per chunk
            toks_h, done_h, pos_h = jax.device_get(
                (toks, self._done, self._pos))
            self.counters["host_syncs"] += 1
            for slot in list(sched.running):
                req = sched.running[slot]
                for t in toks_h[slot]:
                    if t != PAD_TOKEN:
                        req.generated.append(int(t))
                finished = bool(done_h[slot])
                if finished:
                    sched.complete(slot)
                    if self.paged:
                        self.kv.release(slot)
                elif self.paged and self.cfg.sliding_window is not None:
                    # SWA: positions behind pos - window are masked out of
                    # attention; release their pages back to the pool
                    self.counters["pages_trimmed"] += self.kv.trim(
                        slot, int(pos_h[slot]) - self.cfg.sliding_window)
        return {r.rid: np.asarray(r.generated, np.int32)
                for r in sched.finished}

    # ------------------------------------------------------- legacy API

    def generate(self, params, batch: Dict[str, Array], *, max_new: int,
                 temperature: float = 0.0,
                 key: Optional[Array] = None) -> Array:
        """Single-batch generation (old API), served by the new engine.

        Returns (B, max_new) tokens; rows that hit EOS early are padded
        with the EOS id."""
        tokens = np.asarray(batch["tokens"])
        b = tokens.shape[0]
        reqs = []
        for i in range(b):
            req = Request(rid=i, prompt=tokens[i], max_new=max_new)
            req.extras = {k: np.asarray(v[i:i + 1])
                          for k, v in batch.items() if k != "tokens"}
            reqs.append(req)
        out = self.run(params, reqs, key=key, temperature=temperature)
        pad = self.eos_id if self.eos_id is not None else 0
        rows = []
        for i in range(b):
            row = out[i]
            if len(row) < max_new:
                row = np.concatenate(
                    [row, np.full(max_new - len(row), pad, np.int32)])
            rows.append(row)
        return jnp.asarray(np.stack(rows))

    def generate_pertoken(self, params, batch: Dict[str, Array], *,
                          max_new: int, temperature: float = 0.0,
                          key: Optional[Array] = None) -> Array:
        """The pre-rebuild per-token loop: one jit dispatch per token.

        Kept as the benchmark baseline and as a cross-check oracle."""
        if not hasattr(self, "_legacy_prefill"):
            cfg, ctx = self.cfg, self.ctx

            def prefill(params, batch):
                return api.prefill_fn(params, batch, cfg, ctx, self.window)

            def decode(params, token, cache):
                return api.decode_fn(params, token, cache, cfg, ctx)

            self._legacy_prefill = jax.jit(prefill)
            self._legacy_decode = jax.jit(decode, donate_argnums=(2,))

        def pick(logits, k):
            last = logits[:, -1, :].astype(jnp.float32)
            if temperature <= 0.0 or k is None:
                return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            return jax.random.categorical(
                k, last / temperature, axis=-1)[:, None].astype(jnp.int32)

        logits, cache = self._legacy_prefill(params, batch)
        tokens = []
        tok = pick(logits, key)
        for i in range(max_new):
            tokens.append(tok)
            logits, cache = self._legacy_decode(params, tok, cache)
            key_i = (jax.random.fold_in(key, i + 1)
                     if key is not None else None)
            tok = pick(logits, key_i)
            self.counters["pertoken_steps"] += 1
        return jnp.concatenate(tokens, axis=1)


def quantize_weights(params: Any, dtype=jnp.float8_e4m3fn) -> Any:
    """Weight-only storage quantization (embeddings/norms stay bf16)."""

    def leaf(p: Array) -> Array:
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dtype)
        return p

    return jax.tree.map(leaf, params)
